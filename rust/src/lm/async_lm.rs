//! Completion-queue generator backend: resolves [`PendingBatch::Ticket`]s
//! on a dedicated off-thread worker.
//!
//! [`AsyncLm`] wraps any `StepGenerator + Send` and turns its synchronous
//! decode into a genuinely two-phase one: `submit_batch` snapshots the
//! search tree (cheap — [`crate::tree::SearchTree`] is struct-of-arrays),
//! enqueues the request on an mpsc channel, and returns a ticket
//! immediately; a background completion worker owns the inner generator,
//! drains the queue FIFO, and posts results to a completion channel that
//! `poll_batch` blocks on (with a ticket-ordered reorder buffer for
//! out-of-order polls).
//!
//! Determinism: the inner generator's RNG advances on the worker in queue
//! order, and the queue order *is* the submit order — so what gets sampled
//! is byte-identical to running the inner generator synchronously. Only
//! *when* the host blocks changes, which is exactly the serve scheduler's
//! determinism contract (scheduling changes when/where/cost, never what).
//!
//! Latency realization: the worker sleeps the inner generator's
//! [`StepGenerator::decode_overhead_seconds`] hint before computing each
//! batch. For [`super::InjectedLatency`] this turns the *modeled* decode
//! latency into *wall-clock* latency — concurrent sessions' sleeps overlap
//! across worker threads, so a shard's decode phase costs ~one hint instead
//! of one per session, which is the measured overlap win
//! `benches/table2_throughput.rs` reports.
//!
//! The worker is spawned lazily on first submit and joined on drop, so an
//! `AsyncLm` that never decodes costs nothing and a finished serve leaks no
//! threads.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{PendingBatch, StepGenerator};
use crate::tree::{NodeId, SearchTree, StepInfo};
use crate::util::error::Result;

/// One submitted decode batch in flight to the completion worker.
struct Job {
    ticket: u64,
    tree: SearchTree,
    requests: Vec<(NodeId, usize)>,
}

/// Channel ends + join handle of a live completion worker.
struct Worker {
    to_worker: Sender<Job>,
    from_worker: Receiver<(u64, Vec<Vec<StepInfo>>)>,
    handle: Option<JoinHandle<()>>,
}

/// Off-thread completion-queue wrapper around a synchronous generator.
pub struct AsyncLm<G: StepGenerator + Send + 'static> {
    /// Inner generator until the completion worker takes ownership of it
    /// (first submit).
    inner: Option<G>,
    worker: Option<Worker>,
    next_ticket: u64,
    /// Tickets submitted and not yet redeemed — the set a poll is allowed
    /// to wait on (a foreign or double-polled ticket fails fast instead of
    /// blocking forever).
    outstanding: BTreeSet<u64>,
    /// Completions that arrived ahead of their poll, keyed by ticket.
    done: BTreeMap<u64, Vec<Vec<StepInfo>>>,
    // Prompt surface + latency hint, cached before the inner generator
    // moves to the worker thread.
    prompt_tokens: usize,
    prompt_token_ids: Option<Vec<u32>>,
    overhead_hint: f64,
}

impl<G: StepGenerator + Send + 'static> AsyncLm<G> {
    pub fn new(inner: G) -> Self {
        let prompt_tokens = inner.prompt_tokens();
        let prompt_token_ids = inner.prompt_token_ids();
        let overhead_hint = inner.decode_overhead_seconds();
        Self {
            inner: Some(inner),
            worker: None,
            next_ticket: 0,
            outstanding: BTreeSet::new(),
            done: BTreeMap::new(),
            prompt_tokens,
            prompt_token_ids,
            overhead_hint,
        }
    }

    /// True once the completion worker has been spawned (tests).
    pub fn worker_spawned(&self) -> bool {
        self.worker.is_some()
    }

    fn ensure_worker(&mut self) -> &mut Worker {
        if self.worker.is_none() {
            let mut lm = self.inner.take().expect("inner generator already moved to a worker");
            let hint = self.overhead_hint;
            let (to_worker, jobs) = channel::<Job>();
            let (results, from_worker) = channel();
            let handle = std::thread::Builder::new()
                .name("async-lm-completion".into())
                .spawn(move || {
                    // FIFO drain = submit order: the inner RNG advances in
                    // exactly the order a synchronous caller would drive it.
                    while let Ok(job) = jobs.recv() {
                        if hint > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(hint));
                        }
                        let out = lm.expand_batch(&job.tree, &job.requests);
                        if results.send((job.ticket, out)).is_err() {
                            break; // owner dropped mid-flight
                        }
                    }
                })
                .expect("spawn async decode completion worker");
            self.worker = Some(Worker { to_worker, from_worker, handle: Some(handle) });
        }
        self.worker.as_mut().expect("just ensured")
    }
}

impl<G: StepGenerator + Send + 'static> StepGenerator for AsyncLm<G> {
    fn expand(&mut self, tree: &SearchTree, leaf: NodeId, n: usize) -> Vec<StepInfo> {
        // Route the scalar entry point through the queue so the RNG order
        // stays the submit order even when callers mix the two surfaces.
        let handle = self.submit_batch(tree, &[(leaf, n)]);
        let mut out = self.poll_batch(handle);
        out.pop().expect("one request yields one result")
    }

    fn submit_batch(&mut self, tree: &SearchTree, requests: &[(NodeId, usize)]) -> PendingBatch {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.outstanding.insert(ticket);
        let job = Job { ticket, tree: tree.clone(), requests: requests.to_vec() };
        self.ensure_worker()
            .to_worker
            .send(job)
            .expect("async decode completion worker exited early (inner generator panicked?)");
        PendingBatch::Ticket(ticket)
    }

    fn try_poll_batch(&mut self, batch: PendingBatch) -> Result<Vec<Vec<StepInfo>>> {
        let id = match batch {
            // Tolerated for symmetry with the blanket adapter (a Ready
            // handle carries its own results).
            PendingBatch::Ready(results) => return Ok(results),
            PendingBatch::Ticket(id) => id,
        };
        if !self.outstanding.remove(&id) {
            crate::bail!(
                "poll_batch: ticket {id} was never issued by this async generator \
                 or was already redeemed (handle crossed generators?)"
            );
        }
        if let Some(results) = self.done.remove(&id) {
            return Ok(results);
        }
        let worker = self.worker.as_mut().expect("outstanding ticket implies a live worker");
        loop {
            let (ticket, results) = worker.from_worker.recv().map_err(|_| {
                crate::err!(
                    "async decode completion worker disconnected while ticket {id} \
                     was in flight (inner generator panicked?)"
                )
            })?;
            if ticket == id {
                return Ok(results);
            }
            self.done.insert(ticket, results);
        }
    }

    fn decode_overhead_seconds(&self) -> f64 {
        // Transparent: the modeled hint is unchanged; this wrapper merely
        // *realizes* it as wall time on the worker.
        self.overhead_hint
    }

    fn prompt_tokens(&self) -> usize {
        self.prompt_tokens
    }

    fn prompt_token_ids(&self) -> Option<Vec<u32>> {
        self.prompt_token_ids.clone()
    }
}

impl<G: StepGenerator + Send + 'static> Drop for AsyncLm<G> {
    fn drop(&mut self) {
        // Join-on-drop: closing the job channel ends the worker loop; the
        // join guarantees no thread outlives its generator (repeated serves
        // must not accumulate leaked completion workers).
        if let Some(Worker { to_worker, from_worker, handle }) = self.worker.take() {
            drop(to_worker);
            drop(from_worker);
            if let Some(handle) = handle {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::{InjectedLatency, SynthLm};
    use crate::workload::{ProblemSet, WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

    fn make() -> SynthLm {
        let spec = WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM);
        let p = ProblemSet::generate(&spec, 1, 9).problems.remove(0);
        SynthLm::new(p, 1)
    }

    fn assert_same(a: &[Vec<StepInfo>], b: &[Vec<StepInfo>]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.len(), y.len());
            for (s, t) in x.iter().zip(y) {
                assert_eq!(s.path_id, t.path_id);
                assert_eq!(s.sem, t.sem);
                assert_eq!(s.tokens, t.tokens);
                assert_eq!(s.paraphrase, t.paraphrase);
            }
        }
    }

    #[test]
    fn async_samples_match_sync_in_submit_order() {
        let mut sync = make();
        let mut asynk = AsyncLm::new(make());
        let mut tree = SearchTree::new();
        let root = tree.init_root(sync.prompt_tokens());
        assert!(!asynk.worker_spawned(), "worker spawn is lazy");
        let requests_a = [(root, 4usize), (root, 3usize)];
        let requests_b = [(root, 2usize)];
        let expected_a = sync.expand_batch(&tree, &requests_a);
        let expected_b = sync.expand_batch(&tree, &requests_b);
        let ha = asynk.submit_batch(&tree, &requests_a);
        let hb = asynk.submit_batch(&tree, &requests_b);
        assert!(ha.is_ticket() && hb.is_ticket(), "async backend defers behind tickets");
        assert!(asynk.worker_spawned());
        // out-of-order redemption exercises the reorder buffer
        let got_b = asynk.poll_batch(hb);
        let got_a = asynk.poll_batch(ha);
        assert_same(&expected_a, &got_a);
        assert_same(&expected_b, &got_b);
    }

    #[test]
    fn expand_routes_through_the_queue() {
        let mut sync = make();
        let mut asynk = AsyncLm::new(make());
        let mut tree = SearchTree::new();
        let root = tree.init_root(sync.prompt_tokens());
        let expected = sync.expand(&tree, root, 5);
        let got = asynk.expand(&tree, root, 5);
        assert_same(std::slice::from_ref(&expected), std::slice::from_ref(&got));
    }

    #[test]
    fn foreign_and_double_polled_tickets_fail_fast() {
        let mut asynk = AsyncLm::new(make());
        let err = asynk.try_poll_batch(PendingBatch::Ticket(7)).unwrap_err();
        assert!(err.0.contains("never issued"), "{err}");
        let mut tree = SearchTree::new();
        let root = tree.init_root(asynk.prompt_tokens());
        let handle = asynk.submit_batch(&tree, &[(root, 2)]);
        let PendingBatch::Ticket(id) = handle else { panic!("expected a ticket") };
        assert_eq!(asynk.poll_batch(PendingBatch::Ticket(id)).len(), 1);
        // second redemption of the same ticket degrades gracefully instead
        // of blocking on the completion queue forever
        let err = asynk.try_poll_batch(PendingBatch::Ticket(id)).unwrap_err();
        assert!(err.0.contains("already redeemed"), "{err}");
    }

    #[test]
    fn latency_hint_is_preserved_and_realized() {
        let mut asynk = AsyncLm::new(InjectedLatency::new(make(), 0.05));
        assert_eq!(asynk.decode_overhead_seconds(), 0.05);
        let mut tree = SearchTree::new();
        let root = tree.init_root(asynk.prompt_tokens());
        let t0 = std::time::Instant::now();
        let h1 = asynk.submit_batch(&tree, &[(root, 2)]);
        let h2 = asynk.submit_batch(&tree, &[(root, 2)]);
        let submitted = t0.elapsed();
        let _ = asynk.poll_batch(h1);
        let _ = asynk.poll_batch(h2);
        let polled = t0.elapsed();
        assert!(submitted.as_secs_f64() < 0.05, "submit must not block on the sleep");
        assert!(polled.as_secs_f64() >= 0.1, "worker realizes the hint per batch");
    }

    #[test]
    fn drop_joins_the_completion_worker() {
        // Repeated construct/submit/drop cycles must not leak threads; the
        // join-on-drop makes each cycle self-contained (the release-mode
        // --test-threads=1 CI pass watches this for flakes).
        for _ in 0..16 {
            let mut asynk = AsyncLm::new(make());
            let mut tree = SearchTree::new();
            let root = tree.init_root(asynk.prompt_tokens());
            let h = asynk.submit_batch(&tree, &[(root, 1)]);
            let _ = asynk.poll_batch(h);
            drop(asynk);
        }
    }
}
