//! Step embedders for the semantic-coverage term (paper §4.2).
//!
//! * [`HashEmbedder`] — simulation path: the embedding of a step is a unit
//!   vector determined by its semantic group, plus a small paraphrase-variant
//!   perturbation. Paraphrases of the same idea land close (cosine ≈ 1),
//!   different approaches land far — the property the paper's BERT math
//!   embedder provides and clustering consumes.
//! * [`crate::engine::pjrt_lm::PjrtEmbedder`] — the tiny encoder executed via
//!   the AOT artifacts over surface token ids (real-compute path).

use crate::tree::{NodeId, SearchTree};
use crate::util::rng::Rng;
use crate::util::simd;

/// Normalize `v` to unit length in place (8-lane blocked sum of squares,
/// f64 accumulation — same bytes with SIMD on or off).
fn normalize(v: &mut [f32]) {
    let norm = (simd::sum_sq(v).sqrt() as f32).max(1e-6);
    simd::div_scalar_f32(v, norm);
}

/// Embeds the *latest step* of trajectories (what ETS clusters).
pub trait Embedder {
    fn embed(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<Vec<f32>>;
    fn dim(&self) -> usize;
}

/// Deterministic group-hash embedder.
pub struct HashEmbedder {
    pub dim: usize,
    /// Scale of the paraphrase jitter relative to the group direction.
    pub jitter: f32,
}

impl Default for HashEmbedder {
    fn default() -> Self {
        Self { dim: 32, jitter: 0.15 }
    }
}

impl HashEmbedder {
    fn unit_from_seed(&self, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut v: Vec<f32> = (0..self.dim).map(|_| r.normal() as f32).collect();
        normalize(&mut v);
        v
    }
}

impl Embedder for HashEmbedder {
    fn embed(&mut self, tree: &SearchTree, nodes: &[NodeId]) -> Vec<Vec<f32>> {
        nodes
            .iter()
            .map(|&id| {
                let step = tree.get(id).step;
                let base = self.unit_from_seed(step.path_id.wrapping_mul(0xD134_2543_DE82_EF95) ^ 0xE7);
                let noise =
                    self.unit_from_seed(step.paraphrase.wrapping_mul(0xA24B_AED4_963E_E407) ^ 0x51);
                let mut v: Vec<f32> = base
                    .iter()
                    .zip(&noise)
                    .map(|(b, n)| b + self.jitter * n)
                    .collect();
                normalize(&mut v);
                v
            })
            .collect()
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::agglomerative;
    use crate::tree::StepInfo;
    use crate::util::stats::cosine;

    fn tree_with_steps(steps: &[(u64, u64)]) -> (SearchTree, Vec<NodeId>) {
        let mut t = SearchTree::new();
        let root = t.init_root(1);
        let ids = steps
            .iter()
            .map(|&(sem, paraphrase)| {
                let path_id = crate::workload::extend_path_id(0, sem);
                t.add_child(
                    root,
                    StepInfo { tokens: 1, sem, paraphrase, path_id, ..Default::default() },
                    0.0,
                )
            })
            .collect();
        (t, ids)
    }

    #[test]
    fn same_group_different_context_is_not_redundant() {
        // identical surface step under different parents -> far embeddings
        let mut t = SearchTree::new();
        let root = t.init_root(1);
        let p1 = crate::workload::extend_path_id(0, 1);
        let p2 = crate::workload::extend_path_id(0, 2);
        let a = t.add_child(root, StepInfo { tokens: 1, sem: 7, paraphrase: 3,
            path_id: crate::workload::extend_path_id(p1, 7), ..Default::default() }, 0.0);
        let b = t.add_child(root, StepInfo { tokens: 1, sem: 7, paraphrase: 3,
            path_id: crate::workload::extend_path_id(p2, 7), ..Default::default() }, 0.0);
        let mut e = HashEmbedder::default();
        let v = e.embed(&t, &[a, b]);
        assert!(cosine(&v[0], &v[1]) < 0.5);
    }

    #[test]
    fn paraphrases_close_groups_far() {
        let (t, ids) = tree_with_steps(&[(1, 10), (1, 20), (2, 10), (3, 99)]);
        let mut e = HashEmbedder::default();
        let v = e.embed(&t, &ids);
        let same = cosine(&v[0], &v[1]);
        let diff = cosine(&v[0], &v[2]);
        assert!(same > 0.9, "paraphrase cosine {same}");
        assert!(diff < 0.5, "cross-group cosine {diff}");
    }

    #[test]
    fn embeddings_are_unit_norm_and_deterministic() {
        let (t, ids) = tree_with_steps(&[(5, 1), (5, 1)]);
        let mut e = HashEmbedder::default();
        let v = e.embed(&t, &ids);
        assert_eq!(v[0], v[1]);
        let n = v[0].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-4);
    }

    #[test]
    fn clustering_recovers_semantic_groups() {
        // 3 groups × 4 paraphrases — agglomerative clustering at the ETS
        // threshold must recover exactly the groups.
        let steps: Vec<(u64, u64)> =
            (0..3).flat_map(|g| (0..4).map(move |p| (g, g * 100 + p))).collect();
        let (t, ids) = tree_with_steps(&steps);
        let mut e = HashEmbedder::default();
        let v = e.embed(&t, &ids);
        let c = agglomerative(&v, 0.3);
        assert_eq!(c.num_clusters, 3, "assignment {:?}", c.assignment);
        for g in 0..3 {
            let cid = c.assignment[g * 4];
            for p in 0..4 {
                assert_eq!(c.assignment[g * 4 + p], cid);
            }
        }
    }
}
