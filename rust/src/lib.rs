//! # ETS: Efficient Tree Search for Inference-Time Scaling
//!
//! A three-layer reproduction of *"ETS: Efficient Tree Search for
//! Inference-Time Scaling"* (Hooper et al., 2025):
//!
//! * **L3 (this crate)** — the serving coordinator: request routing, dynamic
//!   batching, PRM-guided tree search (beam / DVTS / REBASE / **ETS**), a
//!   radix-tree KV-cache manager, an ILP cost-model solver, and agglomerative
//!   clustering for the semantic-coverage term.
//! * **L2 (python/compile/model.py, build time)** — a JAX transformer
//!   (prefill, KV-cached decode, PRM head, embedder), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build time)** — Pallas kernels for the
//!   attention hot-spot (shared-prefix tree attention), interpret mode.
//!
//! Python never runs on the request path: `runtime` (behind the
//! off-by-default `pjrt` feature) loads the compiled artifacts via PJRT and
//! executes them from rust. The default build is fully offline: search,
//! the batched [`engine::BatchEngine`], the radix KV cache, and the
//! multi-problem [`coordinator::serve`] loop run against the calibrated
//! synthetic workload with no external dependencies.

pub mod cluster;
pub mod coordinator;
pub mod embed;
pub mod engine;
pub mod eval;
pub mod ilp;
pub mod kvcache;
pub mod lm;
pub mod metrics;
pub mod obs;
pub mod reward;
pub mod search;
pub mod tree;
pub mod util;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod workload;
