//! Two-track serve tracing: a preallocated per-shard ring-buffer recorder
//! plus a Chrome trace-event JSON emitter (open the file in Perfetto or
//! `chrome://tracing`).
//!
//! **The two-track timestamp rule.** Every serve quantity is either
//! *modeled* (derived from `PerfModel` folds over committed search state —
//! part of the determinism contract) or *executed* (real host behaviour —
//! diagnostic only). The trace keeps the two on separate tracks:
//!
//! * **Modeled track** (`pid 0`, cat `"modeled"`): one timeline per
//!   session, rebuilt at the end of a serve purely from each session's
//!   committed [`StepMetrics`] folded through
//!   [`PerfModel::step_latency`] — a session-local clock that knows nothing
//!   about scheduling. Because scheduling changes *when/where/cost* but
//!   never *what*, this track is **byte-identical across shard counts,
//!   pipeline, and async-decode modes** (the determinism suite pins it).
//! * **Executed track** (`pid 1+shard`, cat `"exec"`): per-shard phase
//!   spans and scheduler lifecycle events (admission, suspension, resume,
//!   migration, demotion/restore, width overrides, spec-plan repair),
//!   stamped on the *global* modeled scheduler clock (Σ per-round max over
//!   shards) with wall-clock diagnostics in `args.wall_us`. This track
//!   legitimately differs across scheduling modes and is excluded from
//!   identity.
//!
//! Recording is allocation-free on the hot path: each shard owns a
//! [`TraceBuf`] ring of preallocated capacity; overflow drops the newest
//! event (counted, never reallocating). Buffers drain at the round barrier
//! in shard-index order, so the merged event stream is deterministic for a
//! fixed configuration.

use crate::engine::PerfModel;
use crate::search::{SearchOutcome, StepMetrics};
use crate::util::json::Json;
use crate::workload::ModelProfile;
use std::time::Instant;

/// Convert modeled seconds to whole microseconds (the Chrome trace unit and
/// the histogram unit). Saturating, deterministic.
#[inline]
pub fn to_us(seconds: f64) -> u64 {
    let us = (seconds * 1e6).round();
    if us <= 0.0 {
        0
    } else if us >= u64::MAX as f64 {
        u64::MAX
    } else {
        us as u64
    }
}

/// One trace event in (a subset of) the Chrome trace-event model:
/// `ph == 'X'` is a duration span, `ph == 'i'` an instant.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// `"modeled"` for the identity-bearing track, `"exec"` otherwise.
    pub cat: &'static str,
    pub ph: char,
    /// Chrome process id: 0 = sessions (modeled), 1+shard = executed.
    pub pid: usize,
    /// Chrome thread id: job id on the modeled track, lane on exec.
    pub tid: usize,
    /// Timestamp in microseconds on the track's modeled clock.
    pub ts_us: u64,
    /// Span duration (0 for instants).
    pub dur_us: u64,
    /// Numeric payload (token counts, ids, `wall_us` diagnostics, ...).
    pub args: Vec<(&'static str, f64)>,
}

impl TraceEvent {
    pub fn span(name: &'static str, pid: usize, tid: usize, ts_us: u64, dur_us: u64) -> Self {
        Self { name, cat: "exec", ph: 'X', pid, tid, ts_us, dur_us, args: vec![] }
    }

    pub fn instant(name: &'static str, pid: usize, tid: usize, ts_us: u64) -> Self {
        Self { name, cat: "exec", ph: 'i', pid, tid, ts_us, dur_us: 0, args: vec![] }
    }

    pub fn arg(mut self, key: &'static str, v: f64) -> Self {
        self.args.push((key, v));
        self
    }

    /// Look up a numeric arg by key.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name)),
            ("cat", Json::str(self.cat)),
            ("ph", Json::Str(self.ph.to_string())),
            ("pid", Json::num(self.pid as f64)),
            ("tid", Json::num(self.tid as f64)),
            ("ts", Json::num(self.ts_us as f64)),
        ];
        if self.ph == 'X' {
            fields.push(("dur", Json::num(self.dur_us as f64)));
        }
        if self.ph == 'i' {
            // instant scope: thread
            fields.push(("s", Json::str("t")));
        }
        if !self.args.is_empty() {
            fields.push((
                "args",
                Json::Obj(
                    self.args.iter().map(|(k, v)| (k.to_string(), Json::num(*v))).collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

/// Preallocated per-shard ring buffer of trace events. `push` never
/// allocates once constructed: past capacity the *newest* event is dropped
/// (and counted) so the retained prefix stays deterministic.
#[derive(Debug)]
pub struct TraceBuf {
    events: Vec<TraceEvent>,
    cap: usize,
    dropped: u64,
    /// Serve-start instant: wall-clock diagnostics are microseconds since
    /// this origin. Wall readings ride in `args` and never in `ts_us`.
    t0: Instant,
}

impl TraceBuf {
    /// Default per-shard capacity between barrier drains.
    pub const DEFAULT_CAPACITY: usize = 4096;

    pub fn new(cap: usize, t0: Instant) -> Self {
        Self { events: Vec::with_capacity(cap), cap, dropped: 0, t0 }
    }

    /// Microseconds of wall clock since the serve started (diagnostic).
    pub fn wall_us(&self) -> u64 {
        self.t0.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Record an event, stamping the wall-clock diagnostic arg. Drops the
    /// event (counted) when the ring is full.
    pub fn push(&mut self, ev: TraceEvent) {
        if self.events.len() < self.cap {
            let wall = self.wall_us();
            self.events.push(ev.arg("wall_us", wall as f64));
        } else {
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain into `out` (round-barrier merge), retaining the ring's
    /// allocation for the next round.
    pub fn drain_into(&mut self, out: &mut Vec<TraceEvent>) {
        out.extend(self.events.drain(..));
    }
}

/// The merged trace of one serve run, carried on
/// [`crate::coordinator::ServeReport`] when tracing is enabled.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeTrace {
    /// Identity-bearing modeled track (session-local clocks, pid 0).
    pub modeled: Vec<TraceEvent>,
    /// Executed/diagnostic track (global scheduler clock + wall args).
    pub exec: Vec<TraceEvent>,
    /// Events dropped by full ring buffers (0 in every shipped config).
    pub dropped: u64,
}

impl ServeTrace {
    /// Count exec-track events by name (the audit's trace side).
    pub fn count(&self, name: &str) -> u64 {
        self.exec.iter().filter(|e| e.name == name).count() as u64
    }

    /// Sum an arg over exec-track events of one name (token reconciliation).
    pub fn sum_arg(&self, name: &str, key: &str) -> f64 {
        self.exec
            .iter()
            .filter(|e| e.name == name)
            .filter_map(|e| e.get(key))
            .sum()
    }

    /// Emit the full two-track Chrome trace-event JSON document.
    pub fn chrome_json(&self, n_shards: usize) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.modeled.len() + self.exec.len() + 8);
        // process-name metadata rows so Perfetto labels the tracks
        let name_meta = |pid: usize, label: &str| {
            Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(0.0)),
                (
                    "args",
                    Json::obj(vec![("name", Json::str(label))]),
                ),
            ])
        };
        events.push(name_meta(0, "sessions (modeled)"));
        for s in 0..n_shards {
            events.push(name_meta(1 + s, &format!("shard {s} (exec)")));
        }
        events.push(name_meta(1 + n_shards, "coordinator (wall)"));
        events.extend(self.modeled.iter().map(TraceEvent::to_json));
        events.extend(self.exec.iter().map(TraceEvent::to_json));
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("dropped_events", Json::num(self.dropped as f64)),
        ])
    }

    /// Serialize only the modeled track — the byte-identity surface the
    /// determinism suite and CI compare across scheduling modes.
    pub fn modeled_json(&self) -> String {
        Json::Arr(self.modeled.iter().map(TraceEvent::to_json).collect()).to_string_compact()
    }
}

/// Coordinator-side trace recorder: owns the merged exec-track event list
/// and the serve-start wall origin. Worker-shard events arrive through
/// [`CoordTracer::drain_shard`] at the round barrier in shard-index order;
/// coordinator phase spans land on the dedicated "coordinator (wall)"
/// Chrome process (`pid 1 + n_shards`) with wall-clock timestamps, clearly
/// segregated from the modeled-clock shard timelines.
#[derive(Debug)]
pub struct CoordTracer {
    pub events: Vec<TraceEvent>,
    n_shards: usize,
    t0: Instant,
}

impl CoordTracer {
    pub fn new(n_shards: usize, t0: Instant) -> Self {
        Self { events: Vec::new(), n_shards, t0 }
    }

    pub fn t0(&self) -> Instant {
        self.t0
    }

    /// Microseconds of wall clock since the serve started.
    pub fn wall_us(&self) -> u64 {
        self.t0.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Record a coordinator-side event, stamping the wall diagnostic.
    pub fn push(&mut self, ev: TraceEvent) {
        let w = self.wall_us();
        self.events.push(ev.arg("wall_us", w as f64));
    }

    /// Record a coordinator phase span on the wall-clock process: the span
    /// runs from `started_us` (a prior [`CoordTracer::wall_us`] reading) to
    /// now. Both endpoints are wall clock — this process never mixes
    /// modeled timestamps.
    pub fn wall_phase(&mut self, name: &'static str, started_us: u64) {
        let now = self.wall_us();
        self.events.push(TraceEvent::span(
            name,
            1 + self.n_shards,
            0,
            started_us,
            now.saturating_sub(started_us),
        ));
    }

    /// Round-barrier merge: move one shard ring's events into the merged
    /// stream, restamping each onto the global modeled clock at `ts_us`
    /// (the round's start — worker threads do not know the global clock;
    /// their wall readings ride along in `args.wall_us`).
    pub fn drain_shard(&mut self, buf: &mut TraceBuf, ts_us: u64) {
        let start = self.events.len();
        buf.drain_into(&mut self.events);
        for ev in &mut self.events[start..] {
            ev.ts_us = ts_us;
        }
    }
}

/// Build the modeled track from finished outcomes: one session-local
/// timeline per job, in job-id order. Pure function of committed search
/// state and the perf model — byte-identical across every scheduling mode
/// that preserves results (which is all of them).
pub fn modeled_track(
    outcomes: &[Option<SearchOutcome>],
    perf: &PerfModel,
    model: &ModelProfile,
) -> Vec<TraceEvent> {
    let mut events = Vec::new();
    for (id, outcome) in outcomes.iter().enumerate() {
        let Some(o) = outcome else { continue };
        let mut t = 0u64;
        events.push(
            TraceEvent { cat: "modeled", ..TraceEvent::instant("admitted", 0, id, 0) }
                .arg("job", id as f64),
        );
        for (i, step) in o.steps.iter().enumerate() {
            let dur = to_us(perf.step_latency(step, model).seconds);
            events.push(
                TraceEvent { cat: "modeled", ..TraceEvent::span("step", 0, id, t, dur) }
                    .arg("index", i as f64)
                    .arg("new_tokens", step.new_tokens as f64)
                    .arg("model_calls", step.model_calls as f64)
                    .arg("live_kv_tokens", step.live_kv_tokens as f64),
            );
            t = t.saturating_add(dur);
        }
        events.push(
            TraceEvent { cat: "modeled", ..TraceEvent::instant("finished", 0, id, t) }
                .arg("job", id as f64)
                .arg("steps", o.steps.len() as f64)
                .arg("answered", if o.answer.is_some() { 1.0 } else { 0.0 }),
        );
    }
    events
}

/// Session-local modeled completion seconds of one outcome — the fold the
/// modeled track uses, exposed for spot checks.
pub fn session_seconds(o: &SearchOutcome, perf: &PerfModel, model: &ModelProfile) -> f64 {
    o.steps.iter().map(|s: &StepMetrics| perf.step_latency(s, model).seconds).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_drops_newest_and_counts() {
        let mut buf = TraceBuf::new(2, Instant::now());
        for i in 0..5 {
            buf.push(TraceEvent::instant("e", 1, 0, i));
        }
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.dropped(), 3);
        let mut out = vec![];
        buf.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert!(buf.is_empty());
        // retained prefix is the oldest events, each stamped with wall_us
        assert_eq!(out[0].ts_us, 0);
        assert_eq!(out[1].ts_us, 1);
        assert!(out[0].get("wall_us").is_some());
        // ring reuses its allocation after a drain
        buf.push(TraceEvent::instant("e", 1, 0, 9));
        assert_eq!(buf.len(), 1);
    }

    #[test]
    fn chrome_json_parses_and_labels_tracks() {
        let trace = ServeTrace {
            modeled: vec![TraceEvent {
                cat: "modeled",
                ..TraceEvent::span("step", 0, 3, 10, 5)
            }],
            exec: vec![TraceEvent::instant("preempted", 1, 0, 42).arg("job", 7.0)],
            dropped: 0,
        };
        let doc = trace.chrome_json(2);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).expect("chrome trace JSON must parse");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 4 metadata rows (sessions, 2 shards, coordinator) + 2 events
        assert_eq!(events.len(), 6);
        assert!(text.contains("sessions (modeled)"));
        assert!(text.contains("shard 1 (exec)"));
        assert!(text.contains("coordinator (wall)"));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
    }

    #[test]
    fn to_us_saturates() {
        assert_eq!(to_us(-1.0), 0);
        assert_eq!(to_us(0.0), 0);
        assert_eq!(to_us(1.5e-6), 2);
        assert_eq!(to_us(f64::MAX), u64::MAX);
    }
}
