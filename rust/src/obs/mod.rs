//! Deterministic serve observability: two-track tracing, log-bucketed
//! latency histograms, and exportable per-request lifecycle telemetry.
//!
//! Three pillars, all zero-crate and provably invisible to results:
//!
//! * [`trace`] — a preallocated per-shard ring-buffer span/event recorder
//!   wired through [`crate::coordinator::serve`]'s phases and session
//!   lifecycle, emitted as Chrome trace-event JSON (Perfetto-viewable).
//!   Events live on **two tracks**: a *modeled* track derived purely from
//!   committed search state (byte-identical across shard counts and
//!   pipeline/async modes) and an *executed* track carrying the global
//!   scheduler clock plus wall-clock diagnostics (excluded from identity).
//! * [`hist`] — HDR-style log-bucketed fixed-size histograms with exact
//!   merge associativity, feeding per-request TTFT/TPOT/completion latency
//!   and per-phase round durations into `ServeReport` as p50/p90/p99.
//! * [`audit`] — reconciles trace event counts against the pre-existing
//!   aggregate counters (preemptions, migrations, spec-plan hits/misses,
//!   demotions/restores, budget shrinks/grants) so the trace provably tells
//!   the same story as the ledgers.
//!
//! [`report`] (text tables, JSON dumps, Prometheus exposition) moved here
//! from the old `metrics` module.

pub mod audit;
pub mod hist;
pub mod report;
pub mod trace;

pub use hist::{Histogram, ServeLatency};
pub use trace::{modeled_track, CoordTracer, ServeTrace, TraceBuf, TraceEvent};
