//! Reporting helpers: aligned text tables (the benches print paper-style
//! rows), JSON result dumps, and the Prometheus-style text exposition of a
//! serve run's counters and histograms (`serve --metrics-out`).
//!
//! This is the single reporting home; the old `metrics` module re-exports
//! from here.

use crate::coordinator::ServeReport;
use crate::obs::hist::Histogram;
use crate::util::json::Json;

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with per-column width = max cell width.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Dump as JSON (list of objects keyed by header).
    pub fn to_json(&self) -> Json {
        Json::arr(self.rows.iter().map(|row| {
            Json::Obj(
                self.header
                    .iter()
                    .zip(row)
                    .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                    .collect(),
            )
        }))
    }

    /// Print and append the JSON form to `target/bench_results.jsonl`.
    pub fn emit(&self) {
        println!("{}", self.render());
        let line = Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("rows", self.to_json()),
        ])
        .to_string_compact();
        let _ = std::fs::create_dir_all("target");
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("target/bench_results.jsonl")
        {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Prometheus-style text exposition of a serve run: every aggregate
/// counter/gauge on [`ServeReport`], plus the latency histograms as
/// summaries with p50/p90/p99 quantiles. Written by
/// `ets serve --metrics-out`; no external crates, just the stable text
/// format scrape pipelines understand.
pub fn prometheus_exposition(report: &ServeReport) -> String {
    let mut out = String::with_capacity(4096);
    let mut counter = |name: &str, help: &str, v: f64| {
        out.push_str(&format!("# HELP ets_{name} {help}\n# TYPE ets_{name} counter\nets_{name} {v}\n"));
    };
    counter("serve_problems", "Problems served to completion", report.outcomes.len() as f64);
    counter("serve_rounds", "Global scheduler rounds executed", report.rounds as f64);
    counter("serve_preemptions", "Sessions preempted under memory pressure", report.preemptions as f64);
    counter("serve_resumes", "Sessions resumed after preemption", report.resumes as f64);
    counter("serve_recompute_tokens", "Tokens re-prefilled by resumes", report.recompute_tokens as f64);
    counter("serve_migrations", "Suspended sessions moved across shards", report.migrations as f64);
    counter("serve_admission_blocked_rounds", "Rounds with admission blocked by watermarks", report.admission_blocked_rounds as f64);
    counter("serve_deferred_commits", "Step commits deferred under pressure", report.deferred_commits as f64);
    counter("serve_hub_hits", "Admissions routed by prompt affinity", report.hub_hits as f64);
    counter("serve_hub_published", "Prefix fingerprints published at barriers", report.hub_published as f64);
    counter("serve_imported_kv_tokens", "KV tokens imported as cross-shard transfers", report.imported_kv_tokens as f64);
    counter("serve_import_transfers", "Import decisions that chose the transfer", report.import_transfers as f64);
    counter("serve_import_recomputes", "Import decisions that chose the recompute", report.import_recomputes as f64);
    counter("serve_spec_plan_hits", "Speculative round plans used as-is", report.spec_plan_hits as f64);
    counter("serve_spec_plan_misses", "Speculative round plans repaired", report.spec_plan_misses as f64);
    counter("serve_transferred_kv_bytes", "Payload bytes moved by the transport plane", report.transferred_kv_bytes as f64);
    counter("serve_recomputed_kv_bytes", "Payload bytes rebuilt locally on resume", report.recomputed_kv_bytes as f64);
    counter("serve_demoted_kv_tokens", "Tokens demoted into the cold tier", report.demoted_kv_tokens as f64);
    counter("serve_restored_kv_tokens", "Tokens restored from the cold tier", report.restored_kv_tokens as f64);
    counter("serve_cold_restores", "Resumes whose tier choice restored", report.cold_restores as f64);
    counter("serve_cold_recomputes", "Resumes whose tier choice recomputed", report.cold_recomputes as f64);
    counter("serve_width_shrinks", "Adaptive-budget width shrinks", report.width_shrinks as f64);
    counter("serve_width_grants", "Adaptive-budget width grants", report.width_grants as f64);
    counter("serve_reclaimed_kv_blocks", "Predicted KV blocks reclaimed by shrinks", report.reclaimed_kv_blocks as f64);
    counter("serve_granted_kv_blocks", "Predicted KV blocks granted to contested sessions", report.granted_kv_blocks as f64);
    let mut gauge = |name: &str, help: &str, v: f64| {
        out.push_str(&format!("# HELP ets_{name} {help}\n# TYPE ets_{name} gauge\nets_{name} {v}\n"));
    };
    gauge("serve_modeled_seconds", "Modeled serving time of the run", report.modeled_seconds);
    gauge("serve_shards", "Shard count the run was scheduled with", report.shards as f64);
    gauge("serve_total_blocks", "Hard global KV block budget", report.total_blocks as f64);
    gauge("serve_peak_used_blocks", "Sum of per-shard block high-water marks", report.peak_used_blocks as f64);
    gauge("serve_peak_resident_kv_tokens", "High-water mark of summed shard caches", report.peak_resident_kv_tokens as f64);
    gauge("serve_max_concurrent", "Most problems simultaneously admitted", report.max_concurrent as f64);
    gauge("serve_throughput_problems_per_sec", "Completed problems per modeled second", report.throughput_problems_per_sec());
    let mut summary = |name: &str, help: &str, h: &Histogram| {
        out.push_str(&format!("# HELP ets_{name}_us {help}\n# TYPE ets_{name}_us summary\n"));
        for (q, v) in [(0.5, h.p50()), (0.9, h.p90()), (0.99, h.p99())] {
            out.push_str(&format!("ets_{name}_us{{quantile=\"{q}\"}} {v}\n"));
        }
        out.push_str(&format!("ets_{name}_us_sum {}\nets_{name}_us_count {}\n", h.mean() * h.count() as f64, h.count()));
    };
    summary("ttft", "Modeled time-to-first-token (microseconds)", &report.latency.ttft);
    summary("tpot", "Modeled time-per-output-token after the first step", &report.latency.tpot);
    summary("completion", "Modeled admission-to-completion latency", &report.latency.completion);
    summary("round_decode", "Modeled decode-phase seconds per shard round", &report.latency.round_decode);
    summary("round_overhead", "Modeled plan+commit seconds per shard round", &report.latency.round_overhead);
    summary("round_seconds", "Modeled seconds per global round (slowest shard)", &report.latency.round_seconds);
    if let Some(trace) = &report.trace {
        let mut c2 = |name: &str, help: &str, v: f64| {
            out.push_str(&format!("# HELP ets_{name} {help}\n# TYPE ets_{name} counter\nets_{name} {v}\n"));
        };
        c2("trace_events", "Exec-track trace events recorded", trace.exec.len() as f64);
        c2("trace_modeled_events", "Modeled-track trace events", trace.modeled.len() as f64);
        c2("trace_dropped_events", "Events dropped by full ring buffers", trace.dropped as f64);
    }
    out
}

/// Format a ratio like "1.8x" (0 → "-").
pub fn ratio(base: f64, x: f64) -> String {
    if x > 0.0 && base > 0.0 {
        format!("{:.2}x", base / x)
    } else {
        "-".into()
    }
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

/// Format a duration in seconds as milliseconds ("12.3ms").
pub fn ms(seconds: f64) -> String {
    format!("{:.1}ms", 1e3 * seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yyy".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn helpers() {
        assert_eq!(ratio(180.0, 100.0), "1.80x");
        assert_eq!(ratio(1.0, 0.0), "-");
        assert_eq!(pct(0.525), "52.5");
        assert_eq!(ms(0.0123), "12.3ms");
    }
}
