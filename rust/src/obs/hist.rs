//! HDR-style log-bucketed histogram with exact merge associativity.
//!
//! Fixed-size (no allocation after construction), integer-only: values are
//! `u64` (the serve path records modeled **microseconds**). Buckets follow
//! the classic HDR layout — a power-of-two major bucket split into
//! `2^SUB_BITS` linear sub-buckets — so relative bucket error is bounded by
//! `2^-SUB_BITS` everywhere while the whole table stays under 2 KB of
//! counters. Merging is element-wise `u64` addition, which makes any merge
//! order of per-shard histograms yield byte-identical counts (and therefore
//! identical quantiles) — the property the determinism suite pins.

use crate::util::json::Json;

/// Sub-bucket resolution: each power-of-two range splits into `2^SUB_BITS`
/// linear buckets (relative error ≤ 1/32 ≈ 3.1%).
pub const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS;
/// Bucket count: one `SUB`-wide row for values `< SUB` (mapped 1:1), plus
/// one `SUB`-wide row per exponent `SUB_BITS..=63`.
pub const BUCKETS: usize = SUB * (64 - SUB_BITS as usize + 1);

/// Map a value to its bucket index. Exact for `v < 32`; above that the
/// bucket holds `[lower, lower + 2^(e-5))` where `e = floor(log2 v)`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let e = 63 - v.leading_zeros(); // e >= SUB_BITS
        let row = (e - SUB_BITS + 1) as usize;
        let sub = ((v >> (e - SUB_BITS)) as usize) & (SUB - 1);
        (row << SUB_BITS) + sub
    }
}

/// Inclusive lower bound of a bucket (the value `quantile` reports).
#[inline]
pub fn bucket_lower(index: usize) -> u64 {
    let row = index >> SUB_BITS;
    let sub = (index & (SUB - 1)) as u64;
    if row == 0 {
        sub
    } else {
        let e = row as u32 + SUB_BITS - 1;
        (SUB as u64 + sub) << (e - SUB_BITS)
    }
}

/// A fixed-size log-bucketed histogram of `u64` samples.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.counts[bucket_index(v)] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration in seconds as whole microseconds (the serve path's
    /// unit for modeled time).
    pub fn record_seconds(&mut self, seconds: f64) {
        self.record(crate::obs::trace::to_us(seconds));
    }

    /// Element-wise add — exactly associative and commutative, so any merge
    /// order of per-shard histograms yields identical quantiles.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the lower bound of the bucket
    /// holding the `ceil(q·total)`-th sample (0 for an empty histogram).
    /// Deterministic and monotone in `q`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // clamp the first/last buckets to observed extremes so
                // singleton histograms report the exact sample
                return bucket_lower(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Compact JSON summary (counts elided; quantiles + moments).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.total as f64)),
            ("mean_us", Json::num(self.mean())),
            ("min_us", Json::num(self.min() as f64)),
            ("max_us", Json::num(self.max as f64)),
            ("p50_us", Json::num(self.p50() as f64)),
            ("p90_us", Json::num(self.p90() as f64)),
            ("p99_us", Json::num(self.p99() as f64)),
        ])
    }
}

/// The serve loop's latency histograms, carried on
/// [`crate::coordinator::ServeReport`]. Per-request metrics are in
/// **modeled time on the global scheduler clock** (they describe the
/// schedule, so they legitimately vary across shard counts and
/// pipeline/async modes — they are *not* identity surfaces); per-phase
/// round metrics come from each round's [`crate::engine::RoundCost`] split.
/// All values are recorded in whole microseconds.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeLatency {
    /// Time-to-first-token: admission → first committed step.
    pub ttft: Histogram,
    /// Time-per-output-token after the first committed step.
    pub tpot: Histogram,
    /// Completion latency: admission → last committed step.
    pub completion: Histogram,
    /// Per shard-round decode-phase seconds.
    pub round_decode: Histogram,
    /// Per shard-round plan + commit seconds.
    pub round_overhead: Histogram,
    /// Per global round modeled seconds (the slowest shard).
    pub round_seconds: Histogram,
}

impl ServeLatency {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ttft", self.ttft.to_json()),
            ("tpot", self.tpot.to_json()),
            ("completion", self.completion.to_json()),
            ("round_decode", self.round_decode.to_json()),
            ("round_overhead", self.round_overhead.to_json()),
            ("round_seconds", self.round_seconds.to_json()),
        ])
    }

    /// The named request-level histograms (the Prometheus exposition and
    /// the JSON percentile dump iterate these).
    pub fn request_metrics(&self) -> [(&'static str, &Histogram); 3] {
        [("ttft", &self.ttft), ("tpot", &self.tpot), ("completion", &self.completion)]
    }

    pub fn phase_metrics(&self) -> [(&'static str, &Histogram); 3] {
        [
            ("round_decode", &self.round_decode),
            ("round_overhead", &self.round_overhead),
            ("round_seconds", &self.round_seconds),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_check;
    use crate::util::proptest::property;

    #[test]
    fn small_values_map_exactly() {
        for v in 0..32u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_lower(v as usize), v);
        }
    }

    #[test]
    fn power_of_two_edges_start_new_rows() {
        // every 2^e for e >= 5 is the first sub-bucket of its row, and the
        // value just below it lands in the previous row's last sub-bucket
        for e in SUB_BITS..64 {
            let v = 1u64 << e;
            let i = bucket_index(v);
            assert_eq!(i & (SUB - 1), 0, "2^{e} must open a row");
            assert_eq!(bucket_lower(i), v, "row lower bound is 2^{e}");
            assert_eq!(bucket_index(v - 1), i - 1, "2^{e}-1 ends prior row");
        }
        // the top value fits in the table
        assert!(bucket_index(u64::MAX) < BUCKETS);
    }

    #[test]
    fn bucket_bounds_bracket_the_value() {
        for &v in &[0u64, 1, 31, 32, 33, 63, 64, 100, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            let lo = bucket_lower(i);
            assert!(lo <= v, "lower {lo} > value {v}");
            if i + 1 < BUCKETS {
                assert!(bucket_lower(i + 1) > v, "value {v} beyond bucket {i}");
            }
            // relative bucket error bounded by 2^-SUB_BITS
            assert!((v - lo) as f64 <= v as f64 / SUB as f64 + 1.0);
        }
    }

    #[test]
    fn singleton_reports_exact_extremes() {
        let mut h = Histogram::new();
        h.record(777);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 777);
        assert_eq!(h.max(), 777);
        assert_eq!(h.p50(), 777);
        assert_eq!(h.p99(), 777);
    }

    #[test]
    fn empty_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    fn sample(rng: &mut crate::util::rng::Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64() >> (rng.next_u64() % 60)).collect()
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        property(64, |rng| {
            let parts: Vec<Vec<u64>> =
                (0..4).map(|_| sample(rng, (rng.next_u64() % 40) as usize)).collect();
            let hists: Vec<Histogram> = parts
                .iter()
                .map(|p| {
                    let mut h = Histogram::new();
                    for &v in p {
                        h.record(v);
                    }
                    h
                })
                .collect();
            // left fold
            let mut left = Histogram::new();
            for h in &hists {
                left.merge(h);
            }
            // reversed order
            let mut rev = Histogram::new();
            for h in hists.iter().rev() {
                rev.merge(h);
            }
            // pairwise tree: (0+1) + (2+3)
            let mut a = hists[0].clone();
            a.merge(&hists[1]);
            let mut b = hists[2].clone();
            b.merge(&hists[3]);
            a.merge(&b);
            prop_check!(left == rev, "merge order changed the histogram");
            prop_check!(left == a, "merge shape changed the histogram");
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                prop_check!(
                    left.quantile(q) == rev.quantile(q) && left.quantile(q) == a.quantile(q),
                    "quantiles diverged across merge orders"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn quantiles_are_monotone_and_bracketed() {
        property(64, |rng| {
            let mut h = Histogram::new();
            for v in sample(rng, 1 + (rng.next_u64() % 200) as usize) {
                h.record(v);
            }
            let mut prev = 0u64;
            for i in 0..=20 {
                let q = i as f64 / 20.0;
                let v = h.quantile(q);
                prop_check!(v >= prev, "quantile not monotone");
                prop_check!(v >= h.min() && v <= h.max(), "quantile outside range");
                prev = v;
            }
            Ok(())
        });
    }
}
