//! Trace ↔ ledger self-audit: the trace must provably tell the same story
//! as the aggregate counters the serve loop has always kept.
//!
//! Every lifecycle event the exec track records (admission, preemption,
//! resume, migration, spec-plan repair, width override, cold-tier
//! demotion/restore) has a pre-existing counter on
//! [`ServeReport`]/`ShardStats` incremented by independent code. Counting
//! the events and diffing against the counters catches a whole class of
//! observability bugs — dropped ring events, double-recorded spans, a phase
//! wired to the wrong hook — without trusting either side.

use crate::coordinator::ServeReport;
use crate::util::json::Json;

/// One reconciliation line: the event-derived count vs the ledger counter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AuditLine {
    pub name: &'static str,
    /// Count (or token/block sum) derived from trace events.
    pub traced: u64,
    /// The pre-existing aggregate counter.
    pub ledger: u64,
}

impl AuditLine {
    pub fn ok(&self) -> bool {
        self.traced == self.ledger
    }
}

/// The full reconciliation of one traced serve run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Audit {
    pub lines: Vec<AuditLine>,
    /// Events dropped by full ring buffers — any drop voids the audit.
    pub dropped_events: u64,
}

impl Audit {
    pub fn ok(&self) -> bool {
        self.dropped_events == 0 && self.lines.iter().all(AuditLine::ok)
    }

    /// Lines that failed reconciliation (empty when [`Audit::ok`]).
    pub fn mismatches(&self) -> Vec<&AuditLine> {
        self.lines.iter().filter(|l| !l.ok()).collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::from("== trace/ledger audit ==\n");
        for l in &self.lines {
            out.push_str(&format!(
                "{:<28} trace={:<10} ledger={:<10} {}\n",
                l.name,
                l.traced,
                l.ledger,
                if l.ok() { "ok" } else { "MISMATCH" }
            ));
        }
        out.push_str(&format!(
            "dropped_events={} => audit {}\n",
            self.dropped_events,
            if self.ok() { "PASS" } else { "FAIL" }
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("dropped_events", Json::num(self.dropped_events as f64)),
            (
                "lines",
                Json::arr(self.lines.iter().map(|l| {
                    Json::obj(vec![
                        ("name", Json::str(l.name)),
                        ("trace", Json::num(l.traced as f64)),
                        ("ledger", Json::num(l.ledger as f64)),
                        ("ok", Json::Bool(l.ok())),
                    ])
                })),
            ),
        ])
    }
}

/// Reconcile a traced [`ServeReport`]'s event stream against its aggregate
/// counters. Returns `None` when the run was not traced (nothing to audit).
pub fn reconcile(report: &ServeReport) -> Option<Audit> {
    let trace = report.trace.as_ref()?;
    let count = |name: &str| trace.count(name);
    let count_where = |name: &str, key: &str| {
        trace
            .exec
            .iter()
            .filter(|e| e.name == name && e.get(key).is_some_and(|v| v > 0.0))
            .count() as u64
    };
    let sum = |name: &str, key: &str| trace.sum_arg(name, key).round() as u64;
    let n = report.outcomes.len() as u64;
    let lines = vec![
        AuditLine { name: "admitted", traced: count("admitted"), ledger: n },
        AuditLine { name: "finished", traced: count("finished"), ledger: n },
        AuditLine { name: "preempted", traced: count("preempted"), ledger: report.preemptions },
        AuditLine { name: "resumed", traced: count("resumed"), ledger: report.resumes },
        AuditLine {
            name: "resume_transfers",
            traced: count_where("resumed", "transfer_tokens"),
            ledger: report.import_transfers,
        },
        AuditLine {
            name: "cold_restores",
            traced: count_where("resumed", "restored_tokens"),
            ledger: report.cold_restores,
        },
        AuditLine {
            name: "restored_kv_tokens",
            traced: sum("resumed", "restored_tokens"),
            ledger: report.restored_kv_tokens,
        },
        AuditLine { name: "migrated", traced: count("migrated"), ledger: report.migrations },
        AuditLine {
            name: "spec_plan_hits",
            traced: count("spec_plan_hit"),
            ledger: report.spec_plan_hits,
        },
        AuditLine {
            name: "spec_plan_misses",
            traced: count("spec_plan_miss"),
            ledger: report.spec_plan_misses,
        },
        AuditLine {
            name: "width_shrinks",
            traced: count("width_shrink"),
            ledger: report.width_shrinks,
        },
        AuditLine {
            name: "width_grants",
            traced: count("width_grant"),
            ledger: report.width_grants,
        },
        AuditLine {
            name: "reclaimed_kv_blocks",
            traced: sum("width_shrink", "blocks"),
            ledger: report.reclaimed_kv_blocks,
        },
        AuditLine {
            name: "granted_kv_blocks",
            traced: sum("width_grant", "blocks"),
            ledger: report.granted_kv_blocks,
        },
        AuditLine {
            name: "demoted_kv_tokens",
            traced: sum("demoted", "tokens"),
            ledger: report.demoted_kv_tokens,
        },
    ];
    Some(Audit { lines, dropped_events: trace.dropped })
}
