//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module is the
//! only place the compiled artifacts are touched at run time. Interchange is
//! HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits protos with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, err};

pub mod xla_shim;
// The offline shim provides the exact `xla` API surface; link real PJRT
// bindings by swapping this alias.
use self::xla_shim as xla;

/// A PJRT client; executables are loaded from `artifacts/`.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Name of the PJRT platform backing this runtime (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it into an executable.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled executable; thin wrapper so callers rarely touch raw xla types.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the elements of the result tuple.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the single
    /// output buffer is a tuple literal which we decompose here.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.decompose_tuple()?)
    }
}

// ---------------------------------------------------------------------------
// Literal construction / extraction helpers.
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given dims from a flat row-major slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if data.len() as i64 != expect {
        bail!("lit_f32: {} elements for dims {dims:?}", data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given dims from a flat row-major slice.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if data.len() as i64 != expect {
        bail!("lit_i32: {} elements for dims {dims:?}", data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract a literal into a Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

// ---------------------------------------------------------------------------
// Artifact set: meta.json + compiled executables.
// ---------------------------------------------------------------------------

/// Dimensions of the compiled LM (from `artifacts/meta.json`).
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub embed_max_seq: usize,
    pub embed_out_dim: usize,
    pub lm_batches: Vec<usize>,
    pub prm_batch: usize,
    pub embed_batch: usize,
}

/// Lazily-compiled set of artifacts rooted at an artifacts directory.
pub struct Artifacts {
    pub runtime: Runtime,
    dir: PathBuf,
    pub dims: ModelDims,
    exes: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Artifacts {
    /// Read `meta.json` and prepare for on-demand compilation.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let meta = Json::parse(&text).map_err(|e| err!("meta.json: {e}"))?;
        let num = |path: &[&str]| -> Result<usize> {
            let mut v = &meta;
            for p in path {
                v = v.get(p).ok_or_else(|| err!("meta.json missing {path:?}"))?;
            }
            v.as_f64().map(|x| x as usize).ok_or_else(|| err!("{path:?} not a number"))
        };
        let dims = ModelDims {
            vocab: num(&["model", "vocab"])?,
            d_model: num(&["model", "d_model"])?,
            n_layers: num(&["model", "n_layers"])?,
            n_heads: num(&["model", "n_heads"])?,
            head_dim: num(&["model", "head_dim"])?,
            max_seq: num(&["model", "max_seq"])?,
            embed_max_seq: num(&["embed", "max_seq"])?,
            embed_out_dim: num(&["embed", "out_dim"])?,
            lm_batches: meta
                .get("lm_batches")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as usize).collect())
                .unwrap_or_default(),
            prm_batch: num(&["prm_batch"])?,
            embed_batch: num(&["embed_batch"])?,
        };
        Ok(Self {
            runtime: Runtime::cpu()?,
            dir,
            dims,
            exes: std::cell::RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch the cached) executable `name` (e.g. "lm_decode_b4").
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let exe = std::rc::Rc::new(self.runtime.load_hlo_text(&path)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Locate the artifacts directory: `$ETS_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("ETS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_helpers_validate_shapes() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit_i32(&[1], &[2]).is_err());
    }
}
