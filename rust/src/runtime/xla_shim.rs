//! Offline stand-in for the `xla` (PJRT bindings) crate.
//!
//! The build environment has no crate registry and no XLA toolchain, so the
//! `pjrt` feature compiles against this shim: the exact API surface
//! `runtime` and `engine::pjrt_lm` use, with real implementations for the
//! host-side pieces ([`Literal`] construction / extraction) and honest
//! runtime errors for anything that needs an actual PJRT backend
//! ([`PjRtClient::cpu`], HLO parsing, execution). Swapping in real bindings
//! is a one-line change in `runtime/mod.rs` (`use self::xla_shim as xla`).

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` + context.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type XlaResult<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str =
    "no real PJRT backend linked: this build uses the offline xla shim (see runtime/xla_shim.rs)";

/// Element storage of a [`Literal`].
#[derive(Clone, Debug)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    fn into_store(v: Vec<Self>) -> Store;
    fn from_store(s: &Store) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_store(v: Vec<Self>) -> Store {
        Store::F32(v)
    }

    fn from_store(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_store(v: Vec<Self>) -> Store {
        Store::I32(v)
    }

    fn from_store(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: flat row-major data + dims. Fully functional in the shim.
#[derive(Clone, Debug)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { store: T::into_store(data.to_vec()), dims: vec![data.len() as i64] }
    }

    fn len(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
            Store::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let expect: i64 = dims.iter().product();
        if matches!(self.store, Store::Tuple(_)) {
            return Err(XlaError("cannot reshape a tuple literal".into()));
        }
        if expect != self.len() as i64 {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { store: self.store.clone(), dims: dims.to_vec() })
    }

    /// Extract the elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        T::from_store(&self.store)
            .ok_or_else(|| XlaError("literal element type mismatch".into()))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> XlaResult<Vec<Literal>> {
        match std::mem::replace(&mut self.store, Store::Tuple(vec![])) {
            Store::Tuple(v) => Ok(v),
            other => {
                self.store = other;
                Err(XlaError("decompose_tuple on a non-tuple literal".into()))
            }
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (backend-only; the shim cannot parse HLO text).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

/// Loaded executable (backend-only).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

/// PJRT client (backend-only; construction reports the missing backend).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        Err(XlaError(NO_BACKEND.into()))
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn backend_calls_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
