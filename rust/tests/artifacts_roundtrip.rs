//! Integration: the AOT artifacts load, compile, and reproduce the golden
//! outputs recorded by python at lowering time — proving the HLO-text
//! interchange preserves the baked weights bit-for-bit enough (f32 ~1e-5).
//!
//! Requires `make artifacts` (skips with a message if artifacts/ is absent,
//! so plain `cargo test` works in a fresh checkout).

use ets::runtime::{lit_f32, lit_i32, to_vec_f32, Artifacts};
use ets::util::json::Json;

fn artifacts() -> Option<(Artifacts, Json)> {
    let dir = ets::runtime::default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: run `make artifacts` first ({} missing)", dir.display());
        return None;
    }
    let golden_text = std::fs::read_to_string(dir.join("golden.json")).ok()?;
    let golden = Json::parse(&golden_text).expect("golden.json parses");
    let arts = Artifacts::open(dir).expect("artifacts open");
    Some((arts, golden))
}

fn golden_vec(g: &Json, key: &str) -> Vec<f32> {
    g.get(key)
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("golden key {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn prefill_decode_match_golden() {
    let Some((arts, golden)) = artifacts() else { return };
    let d = arts.dims.clone();
    let s = d.max_seq;

    // ---- prefill(b=1) on the golden prompt ----
    let prompt: Vec<i32> = golden_vec(&golden, "prefill_tokens16")
        .iter()
        .map(|&x| x as i32)
        .collect();
    let mut tokens = vec![0i32; s];
    tokens[..16].copy_from_slice(&prompt);
    let prefill = arts.executable("lm_prefill_b1").expect("compile prefill");
    let out = prefill
        .run(&[
            lit_i32(&tokens, &[1, s as i64]).unwrap(),
            lit_i32(&[16], &[1]).unwrap(),
        ])
        .expect("prefill run");
    assert_eq!(out.len(), 3, "prefill returns (logits, k, v)");
    let logits = to_vec_f32(&out[0]).unwrap();
    assert_eq!(logits.len(), d.vocab);
    close(
        &logits[..8],
        &golden_vec(&golden, "prefill_logits_head"),
        2e-4,
        "prefill logits",
    );

    // ---- decode one step with the produced KV ----
    let decode = arts.executable("lm_decode_b1").expect("compile decode");
    let tok = golden.get("decode_token").unwrap().as_f64().unwrap() as i32;
    let pos = golden.get("decode_pos").unwrap().as_f64().unwrap() as i32;
    let out2 = decode
        .run(&[
            lit_i32(&[tok], &[1]).unwrap(),
            lit_i32(&[pos], &[1]).unwrap(),
            out[1].clone(),
            out[2].clone(),
        ])
        .expect("decode run");
    let dlogits = to_vec_f32(&out2[0]).unwrap();
    close(
        &dlogits[..8],
        &golden_vec(&golden, "decode_logits_head"),
        2e-4,
        "decode logits",
    );
}

#[test]
fn prm_scores_match_golden() {
    let Some((arts, golden)) = artifacts() else { return };
    let d = arts.dims.clone();
    let s = d.max_seq;
    let b = d.prm_batch;
    let prompt: Vec<i32> = golden_vec(&golden, "prefill_tokens16")
        .iter()
        .map(|&x| x as i32)
        .collect();
    let mut tokens = vec![0i32; b * s];
    tokens[..16].copy_from_slice(&prompt);
    let mut lens = vec![1i32; b];
    lens[0] = 16;
    let prm = arts.executable(&format!("prm_score_b{b}")).expect("compile prm");
    let out = prm
        .run(&[
            lit_i32(&tokens, &[b as i64, s as i64]).unwrap(),
            lit_i32(&lens, &[b as i64]).unwrap(),
        ])
        .expect("prm run");
    let scores = to_vec_f32(&out[0]).unwrap();
    close(&scores, &golden_vec(&golden, "prm_scores"), 2e-4, "prm scores");
    for &sc in &scores {
        assert!((0.0..=1.0).contains(&sc), "score {sc} outside [0,1]");
    }
}

#[test]
fn embedder_matches_golden() {
    let Some((arts, golden)) = artifacts() else { return };
    let d = arts.dims.clone();
    let (b, se) = (d.embed_batch, d.embed_max_seq);
    let mut tokens = vec![0i32; b * se];
    tokens[..5].copy_from_slice(&[3, 1, 4, 1, 5]);
    tokens[se..se + 3].copy_from_slice(&[2, 7, 1]);
    let mut lens = vec![1i32; b];
    lens[0] = 5;
    lens[1] = 3;
    let emb = arts.executable(&format!("embed_b{b}")).expect("compile embed");
    let out = emb
        .run(&[
            lit_i32(&tokens, &[b as i64, se as i64]).unwrap(),
            lit_i32(&lens, &[b as i64]).unwrap(),
        ])
        .expect("embed run");
    let e = to_vec_f32(&out[0]).unwrap();
    close(
        &e[..8],
        &golden_vec(&golden, "embed_head"),
        2e-4,
        "embedding row 0",
    );
    let row1: &[f32] = &e[d.embed_out_dim..2 * d.embed_out_dim];
    let norm = row1.iter().map(|x| x * x).sum::<f32>().sqrt();
    let expect = golden.get("embed_norm_row1").unwrap().as_f64().unwrap() as f32;
    assert!((norm - expect).abs() < 1e-3, "norm {norm} vs {expect}");
}

#[test]
fn tree_attn_artifact_runs_and_is_prefix_consistent() {
    let Some((arts, _)) = artifacts() else { return };
    // shapes from meta: g=8, sp=64, ss=16, H=n_heads, D=head_dim
    let (g, sp, ss) = (8usize, 64usize, 16usize);
    let (h, dd) = (arts.dims.n_heads, arts.dims.head_dim);
    let exe = arts.executable("tree_attn").expect("compile tree_attn");
    // deterministic pseudo-random inputs
    let mut rng = ets::util::rng::Rng::new(42);
    let fill = |rng: &mut ets::util::rng::Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    };
    let q = fill(&mut rng, g * h * dd);
    let kp = fill(&mut rng, h * sp * dd);
    let vp = fill(&mut rng, h * sp * dd);
    let ks = fill(&mut rng, g * h * ss * dd);
    let vs = fill(&mut rng, g * h * ss * dd);
    let slen = vec![ss as i32; g];
    let run = |plen: i32| -> Vec<f32> {
        let out = exe
            .run(&[
                lit_f32(&q, &[g as i64, h as i64, dd as i64]).unwrap(),
                lit_f32(&kp, &[h as i64, sp as i64, dd as i64]).unwrap(),
                lit_f32(&vp, &[h as i64, sp as i64, dd as i64]).unwrap(),
                lit_f32(&ks, &[g as i64, h as i64, ss as i64, dd as i64]).unwrap(),
                lit_f32(&vs, &[g as i64, h as i64, ss as i64, dd as i64]).unwrap(),
                lit_i32(&[plen], &[1]).unwrap(),
                lit_i32(&slen, &[g as i64]).unwrap(),
            ])
            .expect("tree_attn run");
        to_vec_f32(&out[0]).unwrap()
    };
    let full = run(sp as i32);
    let short = run(8);
    assert_eq!(full.len(), g * h * dd);
    assert!(full.iter().all(|x| x.is_finite()));
    // masking must change the result (prefix positions 8.. carry signal)
    let diff: f32 = full.iter().zip(&short).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "prefix_len mask has no effect (diff {diff})");
}
