//! Determinism across execution shapes: `coordinator::par_map` eval and the
//! batched `serve` path must produce identical eval results for 1, 2, and 8
//! workers / concurrent slots at a fixed seed. Per-problem RNG streams are
//! seed-derived and the engine's KV accounting is per-ledger, so neither
//! thread count nor co-scheduling may leak into results.
//!
//! The same holds under *memory pressure*: a hard KV budget tight enough to
//! force admission gating and preemption/resume must leave every answer and
//! every per-problem KV/token count identical to the effectively-unbounded
//! run at the same seed — scheduling must never change search outcomes.

use ets::coordinator::ServeOptions;
use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{evaluate_serve, evaluate_serve_with, evaluate_with_workers, EvalConfig, PolicySpec};
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn cfg(policy: PolicySpec) -> EvalConfig {
    EvalConfig {
        spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
        policy,
        width: 16,
        n_problems: 8,
        seed: 20260730,
        max_steps: SYNTH_MATH500.n_steps + 6,
    }
}

fn fingerprint(r: &ets::eval::EvalReport) -> (usize, String, String, Vec<(bool, u64, u64)>) {
    (
        r.n_correct,
        format!("{:.6}", r.mean_kv_tokens),
        format!("{:.6}", r.mean_new_tokens),
        r.per_problem.clone(),
    )
}

#[test]
fn par_map_workers_agree() {
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 1));
    for workers in [2, 8] {
        assert_eq!(
            base,
            fingerprint(&evaluate_with_workers(&cfg, workers)),
            "worker count {workers} changed eval results"
        );
    }
}

#[test]
fn serve_concurrency_agrees_with_par_map() {
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let base = fingerprint(&evaluate_with_workers(&cfg, 2));
        for concurrency in [1usize, 2, 8] {
            let perf = PerfModel::new(H100_NVL, true, concurrency);
            let served = evaluate_serve(&cfg, concurrency, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "serve concurrency {concurrency} diverged from par_map eval"
            );
            assert!(served.serve.modeled_seconds > 0.0);
        }
        let perf = PerfModel::new(H100_NVL, true, 8);
        let served = evaluate_serve(&cfg, 8, &perf);
        assert!(served.serve.max_concurrent >= 2, "width-8 run should co-schedule");
    }
}

#[test]
fn tight_capacity_preemption_cannot_change_results() {
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let perf = PerfModel::new(H100_NVL, true, 8);
        let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(8), &perf);
        let solo_peak = uncapped
            .serve
            .outcomes
            .iter()
            .map(|o| o.peak_kv_tokens())
            .max()
            .unwrap() as usize;
        // a budget comfortably above any single problem's working set but
        // well below the 8-way co-scheduled one
        let tight_tokens = 2 * solo_peak + 4096;
        assert!(
            uncapped.serve.peak_resident_kv_tokens > tight_tokens,
            "precondition: uncapped peak {} must oversubscribe the tight budget {}",
            uncapped.serve.peak_resident_kv_tokens,
            tight_tokens
        );
        let opts = ServeOptions {
            concurrency: 8,
            capacity_tokens: tight_tokens,
            block_size: 16,
        };
        let capped = evaluate_serve_with(&cfg, &opts, &perf);
        // identical to the uncapped serve AND to the par_map baseline
        assert_eq!(
            fingerprint(&uncapped.report),
            fingerprint(&capped.report),
            "a tight capacity changed search results"
        );
        assert_eq!(
            fingerprint(&evaluate_with_workers(&cfg, 2)),
            fingerprint(&capped.report),
            "capped serve diverged from par_map eval"
        );
        // the budget actually bound: the scheduler visibly intervened and
        // the block budget was never exceeded
        assert!(
            capped.serve.kv_pressure_events() > 0,
            "tight budget produced no pressure events"
        );
        assert!(capped.serve.peak_used_blocks <= capped.serve.total_blocks);
        assert!(
            capped.serve.peak_resident_kv_tokens
                <= capped.serve.total_blocks * opts.block_size
        );
        if capped.serve.preemptions > 0 {
            assert!(capped.serve.resumes > 0, "preempted sessions must resume");
            // note: capped is not necessarily *slower* overall — a smaller
            // resident set can avoid wave fragmentation — but the recompute
            // bill of preemption must be visible in the telemetry
            assert_eq!(
                capped.serve.recompute_tokens,
                capped.serve.batches.iter().map(|b| b.recompute_tokens as u64).sum::<u64>(),
                "recompute accounting must reconcile with the per-round records"
            );
        }
    }
}
