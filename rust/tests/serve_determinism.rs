//! Determinism across execution shapes: `coordinator::par_map` eval and the
//! batched `serve` path must produce identical eval results for 1, 2, and 8
//! workers / concurrent slots at a fixed seed. Per-problem RNG streams are
//! seed-derived and the engine's KV accounting is per-ledger, so neither
//! thread count nor co-scheduling may leak into results.

use ets::engine::{PerfModel, H100_NVL};
use ets::eval::{evaluate_serve, evaluate_with_workers, EvalConfig, PolicySpec};
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn cfg(policy: PolicySpec) -> EvalConfig {
    EvalConfig {
        spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
        policy,
        width: 16,
        n_problems: 8,
        seed: 20260730,
        max_steps: SYNTH_MATH500.n_steps + 6,
    }
}

fn fingerprint(r: &ets::eval::EvalReport) -> (usize, String, String, Vec<(bool, u64, u64)>) {
    (
        r.n_correct,
        format!("{:.6}", r.mean_kv_tokens),
        format!("{:.6}", r.mean_new_tokens),
        r.per_problem.clone(),
    )
}

#[test]
fn par_map_workers_agree() {
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 1));
    for workers in [2, 8] {
        assert_eq!(
            base,
            fingerprint(&evaluate_with_workers(&cfg, workers)),
            "worker count {workers} changed eval results"
        );
    }
}

#[test]
fn serve_concurrency_agrees_with_par_map() {
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let base = fingerprint(&evaluate_with_workers(&cfg, 2));
        for concurrency in [1usize, 2, 8] {
            let perf = PerfModel::new(H100_NVL, true, concurrency);
            let served = evaluate_serve(&cfg, concurrency, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "serve concurrency {concurrency} diverged from par_map eval"
            );
            assert!(served.serve.modeled_seconds > 0.0);
        }
        let perf = PerfModel::new(H100_NVL, true, 8);
        let served = evaluate_serve(&cfg, 8, &perf);
        assert!(served.serve.max_concurrent >= 2, "width-8 run should co-schedule");
    }
}
