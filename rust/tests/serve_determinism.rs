//! Determinism across execution shapes: the (serve-backed) worker eval and
//! the batched `serve` path must produce identical eval results for 1, 2,
//! and 8 workers / concurrent slots at a fixed seed. Per-problem RNG
//! streams are seed-derived and the engine's KV accounting is per-ledger,
//! so neither thread count nor co-scheduling may leak into results.
//!
//! The same holds under *memory pressure*: a hard KV budget tight enough to
//! force admission gating and preemption/resume must leave every answer and
//! every per-problem KV/token count identical to the effectively-unbounded
//! run at the same seed — scheduling must never change search outcomes.
//!
//! And it holds across *shard counts* and *execution modes*: `--shards N`
//! partitions the budget over N shared-nothing engines stepped by N
//! persistent workers (plan → decode → commit rounds over mpsc), with
//! deterministic least-loaded admission and cross-shard migration of stuck
//! sessions — shards ∈ {1, 2, 4} × pipeline {on, off} must be
//! byte-identical per problem, under both ample and tight capacity (and
//! the tight multi-shard runs must actually exercise migration).
//! Pipelining may only change the *modeled cost fold* of a round
//! (`max(decode, plan + commit)` vs their sum), never its contents.

use ets::coordinator::ServeOptions;
use ets::engine::{PerfModel, DEFAULT_KV_CAPACITY, H100_NVL};
use ets::eval::{
    evaluate_serve, evaluate_serve_duplicate_prompts, evaluate_serve_with,
    evaluate_with_workers, EvalConfig, PolicySpec,
};
use ets::util::simd;
use ets::workload::{WorkloadSpec, LLEMMA_34B_SIM, SYNTH_MATH500};

fn cfg(policy: PolicySpec) -> EvalConfig {
    EvalConfig {
        spec: WorkloadSpec::new(&SYNTH_MATH500, &LLEMMA_34B_SIM),
        policy,
        width: 16,
        n_problems: 8,
        seed: 20260730,
        max_steps: SYNTH_MATH500.n_steps + 6,
    }
}

fn fingerprint(r: &ets::eval::EvalReport) -> (usize, String, String, Vec<(bool, u64, u64)>) {
    (
        r.n_correct,
        format!("{:.6}", r.mean_kv_tokens),
        format!("{:.6}", r.mean_new_tokens),
        r.per_problem.clone(),
    )
}

#[test]
fn par_map_workers_agree() {
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 1));
    for workers in [2, 8] {
        assert_eq!(
            base,
            fingerprint(&evaluate_with_workers(&cfg, workers)),
            "worker count {workers} changed eval results"
        );
    }
}

#[test]
fn serve_concurrency_agrees_with_par_map() {
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let base = fingerprint(&evaluate_with_workers(&cfg, 2));
        for concurrency in [1usize, 2, 8] {
            let perf = PerfModel::new(H100_NVL, true, concurrency);
            let served = evaluate_serve(&cfg, concurrency, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "serve concurrency {concurrency} diverged from par_map eval"
            );
            assert!(served.serve.modeled_seconds > 0.0);
        }
        let perf = PerfModel::new(H100_NVL, true, 8);
        let served = evaluate_serve(&cfg, 8, &perf);
        assert!(served.serve.max_concurrent >= 2, "width-8 run should co-schedule");
    }
}

#[test]
fn tight_capacity_preemption_cannot_change_results() {
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let perf = PerfModel::new(H100_NVL, true, 8);
        let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(8), &perf);
        let solo_peak = uncapped
            .serve
            .outcomes
            .iter()
            .map(|o| o.peak_kv_tokens())
            .max()
            .unwrap() as usize;
        // a budget comfortably above any single problem's working set but
        // well below the 8-way co-scheduled one
        let tight_tokens = 2 * solo_peak + 4096;
        assert!(
            uncapped.serve.peak_resident_kv_tokens > tight_tokens,
            "precondition: uncapped peak {} must oversubscribe the tight budget {}",
            uncapped.serve.peak_resident_kv_tokens,
            tight_tokens
        );
        let opts = ServeOptions {
            concurrency: 8,
            capacity_tokens: tight_tokens,
            block_size: 16,
            ..Default::default()
        };
        let capped = evaluate_serve_with(&cfg, &opts, &perf);
        // identical to the uncapped serve AND to the par_map baseline
        assert_eq!(
            fingerprint(&uncapped.report),
            fingerprint(&capped.report),
            "a tight capacity changed search results"
        );
        assert_eq!(
            fingerprint(&evaluate_with_workers(&cfg, 2)),
            fingerprint(&capped.report),
            "capped serve diverged from par_map eval"
        );
        // the budget actually bound: the scheduler visibly intervened and
        // the block budget was never exceeded
        assert!(
            capped.serve.kv_pressure_events() > 0,
            "tight budget produced no pressure events"
        );
        assert!(capped.serve.peak_used_blocks <= capped.serve.total_blocks);
        assert!(
            capped.serve.peak_resident_kv_tokens
                <= capped.serve.total_blocks * opts.block_size
        );
        if capped.serve.preemptions > 0 {
            assert!(capped.serve.resumes > 0, "preempted sessions must resume");
            // note: capped is not necessarily *slower* overall — a smaller
            // resident set can avoid wave fragmentation — but the recompute
            // bill of preemption must be visible in the telemetry
            assert_eq!(
                capped.serve.recompute_tokens,
                capped.serve.batches.iter().map(|b| b.recompute_tokens as u64).sum::<u64>(),
                "recompute accounting must reconcile with the per-round records"
            );
        }
    }
}

#[test]
fn shard_and_pipeline_matrix_is_invisible_at_ample_capacity() {
    // The persistent-worker identity matrix: shards ∈ {1, 2, 4} × pipeline
    // {off, on} must all fold to the same per-problem results as the
    // worker-eval baseline (which itself pins the pre-runtime behavior via
    // the solo run_search identity in the coordinator tests).
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 2));
    for shards in [1usize, 2, 4] {
        let mut modeled = Vec::new();
        for pipeline in [false, true] {
            // one full default-sized engine per shard: capacity never binds
            let opts = ServeOptions {
                concurrency: 8,
                capacity_tokens: DEFAULT_KV_CAPACITY * shards,
                shards,
                pipeline,
                ..Default::default()
            };
            let perf = PerfModel::new(H100_NVL, true, 8);
            let served = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "shards={shards} pipeline={pipeline} changed eval results"
            );
            assert_eq!(served.serve.shards, shards);
            assert_eq!(served.serve.pipeline, pipeline);
            assert_eq!(served.serve.shard_stats.len(), shards);
            assert!(served.serve.modeled_seconds > 0.0);
            assert_eq!(
                served.serve.kv_pressure_events(),
                0,
                "ample capacity must keep the pressure machinery dormant"
            );
            assert_eq!(served.serve.migrations, 0, "no pressure, no migration");
            // every job admitted exactly once across shards
            let admitted: u64 = served.serve.shard_stats.iter().map(|s| s.admitted).sum();
            assert_eq!(admitted, cfg.n_problems as u64);
            // every round's modeled seconds folds its phase decomposition
            // exactly as the mode dictates
            for b in &served.serve.batches {
                let expect = if pipeline {
                    b.decode_seconds.max(b.overhead_seconds)
                } else {
                    b.decode_seconds + b.overhead_seconds
                };
                assert_eq!(b.seconds, expect, "round cost fold mismatch: {b:?}");
            }
            modeled.push(served.serve.modeled_seconds);
        }
        // pipelining can only hide work, never add it
        assert!(
            modeled[1] <= modeled[0],
            "pipelined modeled time {} exceeded lockstep {} at shards={shards}",
            modeled[1],
            modeled[0]
        );
    }
}

#[test]
fn prefix_share_matrix_is_invisible_under_ample_and_tight_capacity() {
    // The prefix hub is a placement/costing layer only: shards ∈ {1, 2, 4}
    // × prefix-share {off, on} must fold to byte-identical per-problem
    // results, under ample capacity and under a tight budget that forces
    // preemption and migration.
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 2));
    for shards in [1usize, 2, 4] {
        for share in [false, true] {
            let opts = ServeOptions {
                concurrency: 8,
                capacity_tokens: DEFAULT_KV_CAPACITY * shards,
                shards,
                prefix_share: share,
                ..Default::default()
            };
            let perf = PerfModel::new(H100_NVL, true, 8);
            let served = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "shards={shards} prefix-share={share} changed eval results"
            );
            assert_eq!(served.serve.prefix_share, share);
            if !share {
                assert_eq!(served.serve.hub_published, 0, "hub must stay off");
                assert_eq!(served.serve.hub_hits, 0);
            }
            // minted prompt ids are globally unique: the hub publishes
            // nothing for them, so affinity can never fire here
            assert_eq!(served.serve.hub_hits, 0);
        }
    }
    // tight: per-shard budgets near one working set, so the 4-shard runs
    // migrate — and the migration cost model must bill each successful
    // migrated-in resume through the min(transfer, recompute) choice
    let mut cfg = cfg;
    cfg.width = 24;
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 12);
    let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(12), &perf);
    let tight_base = fingerprint(&uncapped.report);
    let solo_peak = uncapped
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    let global_budget = 4 * (solo_peak + 4096);
    for shards in [1usize, 4] {
        for share in [false, true] {
            let opts = ServeOptions {
                concurrency: 12,
                capacity_tokens: global_budget,
                block_size: 16,
                shards,
                prefix_share: share,
                ..Default::default()
            };
            let capped = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                tight_base,
                fingerprint(&capped.report),
                "shards={shards} prefix-share={share} under a tight budget \
                 changed eval results"
            );
            if shards == 4 {
                assert!(capped.serve.migrations > 0, "tight 4-shard runs must migrate");
                let billed = capped.serve.migration_transfers
                    + capped.serve.migration_recomputes
                    + capped.serve.migration_cold;
                assert!(
                    billed >= 1,
                    "every successful migrated-in resume must record how it \
                     was billed (migrations {})",
                    capped.serve.migrations
                );
                assert!(
                    billed <= capped.serve.migrations,
                    "more migration bills than migrations"
                );
                if capped.serve.migration_transfers > 0 {
                    assert!(
                        capped.serve.imported_kv_tokens > 0,
                        "a transfer choice must move tokens over the link"
                    );
                    assert!(
                        capped.serve.batches.iter().any(|b| b.transfer_kv_tokens > 0),
                        "transferred tokens must be billed to a round"
                    );
                }
            }
        }
    }
}

#[test]
fn duplicate_prompts_hit_the_hub_and_shrink_resident_blocks() {
    // The workload the hub exists for: 12 problems drawing real prompt ids
    // from a pool of 3, so identical prompts recur. Placement must never
    // change results (shards {1, 4} × share {off, on} all byte-identical),
    // and at 4 shards prompt-affinity must actually fire (hub hit rate > 0)
    // and colocate duplicates so the fleet's mean resident KV blocks drop
    // strictly below the sharing-off run.
    let mut cfg = cfg(PolicySpec::Rebase);
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 4);
    let run = |shards: usize, share: bool| {
        let opts = ServeOptions {
            // concurrency below n_problems so later admissions see a
            // populated hub snapshot (everything admitted in round 0 would
            // trivially bypass affinity)
            concurrency: 4,
            shards,
            prefix_share: share,
            ..Default::default()
        };
        evaluate_serve_duplicate_prompts(&cfg, &opts, &perf, 3)
    };
    let base = run(1, false);
    let base_fp = fingerprint(&base.report);
    for (shards, share) in [(1usize, true), (4, false), (4, true)] {
        let r = run(shards, share);
        assert_eq!(
            base_fp,
            fingerprint(&r.report),
            "shards={shards} prefix-share={share} changed duplicate-prompt results"
        );
    }
    let off = run(4, false);
    let on = run(4, true);
    // affinity fired: admissions after the first wave routed by the hub
    assert!(on.serve.hub_hits > 0, "duplicate prompts must produce hub hits");
    assert!(on.serve.hub_hit_rate() > 0.0);
    assert!(on.serve.hub_published > 0);
    // hub consistency: every published fingerprint was resolvable at audit
    // time — still live on its owner, demoted to its cold tier, or
    // evicted-but-accounted
    assert_eq!(
        on.serve.hub_published,
        on.serve.hub_live_entries + on.serve.hub_demoted_entries + on.serve.hub_evicted_entries,
        "published fingerprints must all be audited live, demoted, or evicted"
    );
    assert!(on.serve.hub_live_entries > 0, "resident prompts must audit live");
    // colocated duplicates deduplicate in the radix caches: strictly fewer
    // resident blocks on average than the spread-out sharing-off run
    assert!(
        on.serve.mean_used_blocks() < off.serve.mean_used_blocks(),
        "prefix sharing must shrink mean resident blocks: on {} vs off {}",
        on.serve.mean_used_blocks(),
        off.serve.mean_used_blocks()
    );
    assert_eq!(off.serve.hub_hits, 0, "sharing off must never consult the hub");
}

#[test]
fn cold_tier_matrix_is_invisible_under_ample_and_tight_capacity() {
    // The host-DRAM spill tier is costing/telemetry only: demotion frees
    // the same HBM blocks in the same order destruction would, restores
    // copy bit-identical payload words back into blocks the resume already
    // reserved, and the SpillArena keeps its own LRU clock — so shards ∈
    // {1, 4} × cold {off, on} must fold to byte-identical per-problem
    // results under ample AND tight capacity, and the tight cold cells
    // must actually demote and restore.
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 2));
    for shards in [1usize, 4] {
        for cold in [0usize, 8 * DEFAULT_KV_CAPACITY] {
            let opts = ServeOptions {
                concurrency: 8,
                capacity_tokens: DEFAULT_KV_CAPACITY * shards,
                shards,
                ..Default::default()
            }
            .cold_tiered(cold);
            let perf = PerfModel::new(H100_NVL, true, 8);
            let served = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "shards={shards} cold={cold} changed eval results"
            );
            // ample capacity: nothing evicts, so nothing can demote
            assert_eq!(served.serve.demoted_kv_tokens, 0);
            assert_eq!(served.serve.restored_kv_tokens, 0);
        }
    }
    // tight: the migration-matrix budget shape, so evictions are plentiful
    // — every cell stays byte-identical, and the cold cells must turn real
    // evictions into demotions and at least one priced restore
    let mut cfg = cfg;
    cfg.width = 24;
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 12);
    let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(12), &perf);
    let tight_base = fingerprint(&uncapped.report);
    let solo_peak = uncapped
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    let global_budget = 4 * (solo_peak + 4096);
    for shards in [1usize, 4] {
        for cold in [0usize, 64 * solo_peak] {
            let opts = ServeOptions {
                concurrency: 12,
                capacity_tokens: global_budget,
                block_size: 16,
                shards,
                ..Default::default()
            }
            .cold_tiered(cold);
            let capped = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                tight_base,
                fingerprint(&capped.report),
                "shards={shards} cold={cold} under a tight budget changed \
                 eval results"
            );
            assert!(capped.serve.peak_used_blocks <= capped.serve.total_blocks);
            assert_eq!(capped.serve.cold_capacity_tokens, cold);
            if cold == 0 {
                assert_eq!(capped.serve.demoted_kv_tokens, 0, "no tier, no demotion");
                assert_eq!(capped.serve.restored_kv_tokens, 0);
            } else {
                assert!(
                    capped.serve.demoted_kv_tokens > 0,
                    "a tight budget with a cold tier must demote (shards={shards})"
                );
                assert!(
                    capped.serve.restored_kv_tokens > 0,
                    "demoted spans must restore over the modeled link \
                     (shards={shards})"
                );
                // the restore bill reconciles: per-round records and
                // per-shard ledgers both fold to the report total
                let per_round: u64 = capped
                    .serve
                    .batches
                    .iter()
                    .map(|b| b.restored_kv_tokens as u64)
                    .sum();
                let per_shard: u64 = capped
                    .serve
                    .shard_stats
                    .iter()
                    .map(|s| s.restored_kv_tokens)
                    .sum();
                assert_eq!(per_round, capped.serve.restored_kv_tokens);
                assert_eq!(per_shard, capped.serve.restored_kv_tokens);
            }
        }
    }
}

#[test]
fn simd_dispatch_is_invisible() {
    // The vectorized substrates (embed cosine, Lance–Williams merges,
    // simplex pivots) contract to perform the *same* 8-lane blocked
    // reduction whether the AVX path or the scalar fallback runs, so
    // forcing scalar execution must reproduce every fingerprint byte for
    // byte — the `ETS_NO_SIMD=1` kill switch can never change results.
    // (force_scalar flips a process-global; the bit-identity contract means
    // concurrently running tests cannot observe the difference either.)
    for policy in [PolicySpec::Rebase, PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 }] {
        let cfg = cfg(policy);
        let perf = PerfModel::new(H100_NVL, true, 8);
        let opts = ServeOptions { concurrency: 8, shards: 2, ..Default::default() };
        let vectorized = fingerprint(&evaluate_serve_with(&cfg, &opts, &perf).report);
        simd::force_scalar(true);
        let scalar = fingerprint(&evaluate_serve_with(&cfg, &opts, &perf).report);
        simd::force_scalar(false);
        assert_eq!(vectorized, scalar, "scalar fallback diverged from vector path");
    }
}

#[test]
fn core_pinning_is_placement_only() {
    // --pin-cores moves worker threads onto fixed cores; it must be
    // invisible in every eval byte. The report records where each worker
    // landed; the inline single-shard scheduler never pins (it would pin
    // the caller's thread for the rest of the process).
    let cfg = cfg(PolicySpec::Rebase);
    let perf = PerfModel::new(H100_NVL, true, 8);
    let run = |shards: usize, pin: bool| {
        let opts = ServeOptions { concurrency: 8, shards, pin_cores: pin, ..Default::default() };
        evaluate_serve_with(&cfg, &opts, &perf)
    };
    let unpinned = run(2, false);
    let pinned = run(2, true);
    assert_eq!(
        fingerprint(&unpinned.report),
        fingerprint(&pinned.report),
        "core pinning changed eval results"
    );
    assert_eq!(unpinned.serve.worker_cores, vec![None, None]);
    assert_eq!(pinned.serve.worker_cores.len(), 2);
    if cfg!(target_os = "linux") {
        assert!(
            pinned.serve.worker_cores.iter().all(|c| c.is_some()),
            "pinning refused on linux: {:?}",
            pinned.serve.worker_cores
        );
    }
    // single shard runs inline on the caller: pinning must be a no-op
    let inline = run(1, true);
    assert_eq!(
        fingerprint(&unpinned.report),
        fingerprint(&inline.report),
        "single-shard run diverged"
    );
    assert_eq!(inline.serve.worker_cores, vec![None], "inline scheduler must never pin");
}

#[test]
fn async_decode_is_invisible() {
    // The true-async data plane (off-thread decode completion via AsyncLm +
    // speculative round planning + executed block transport) is pure
    // scheduling: shards ∈ {1, 4} × async {off, on} must fold to
    // byte-identical per-problem results, at ample capacity and under a
    // tight budget that forces preemption, resume, and migration.
    let cfg = cfg(PolicySpec::Rebase);
    let base = fingerprint(&evaluate_with_workers(&cfg, 2));
    for shards in [1usize, 4] {
        for async_decode in [false, true] {
            let opts = ServeOptions {
                concurrency: 8,
                capacity_tokens: DEFAULT_KV_CAPACITY * shards,
                shards,
                ..Default::default()
            }
            .async_decoded(async_decode);
            let perf = PerfModel::new(H100_NVL, true, 8);
            let served = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                base,
                fingerprint(&served.report),
                "shards={shards} async-decode={async_decode} changed eval results"
            );
            assert_eq!(served.serve.async_decode, async_decode);
            if !async_decode {
                assert_eq!(served.serve.spec_plan_hits, 0, "speculation must stay off");
                assert_eq!(served.serve.spec_plan_misses, 0);
            } else {
                assert!(
                    served.serve.spec_plan_hits > 0,
                    "an async run of many rounds must reuse staged plans"
                );
            }
        }
    }
    // tight: per-shard budgets near one working set (preempt/resume/migrate
    // churn keeps appending slots between staging and the next plan)
    let mut cfg = cfg;
    cfg.width = 24;
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 12);
    let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(12), &perf);
    let tight_base = fingerprint(&uncapped.report);
    let solo_peak = uncapped
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    let global_budget = 4 * (solo_peak + 4096);
    for shards in [1usize, 4] {
        for async_decode in [false, true] {
            let opts = ServeOptions {
                concurrency: 12,
                capacity_tokens: global_budget,
                block_size: 16,
                shards,
                ..Default::default()
            }
            .async_decoded(async_decode);
            let capped = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                tight_base,
                fingerprint(&capped.report),
                "shards={shards} async-decode={async_decode} under a tight \
                 budget changed eval results"
            );
            assert!(capped.serve.peak_used_blocks <= capped.serve.total_blocks);
        }
    }
}

#[test]
fn speculative_planning_repairs_mispredicts_without_changing_results() {
    // Frontier growth between staging and the next plan (admissions landing
    // mid-run via continuous batching, resumes after preemption) is the
    // speculative planner's mispredict case: the staged entries are kept
    // and only the appended tail is planned. A run with more problems than
    // concurrency must therefore record BOTH hits (quiet rounds) and misses
    // (admission rounds) — and stay byte-identical to the sync run.
    let mut cfg = cfg(PolicySpec::Rebase);
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 4);
    let opts = |async_decode: bool| {
        ServeOptions {
            concurrency: 4, // < n_problems: finished slots refill mid-flight
            shards: 2,
            capacity_tokens: DEFAULT_KV_CAPACITY * 2,
            ..Default::default()
        }
        .async_decoded(async_decode)
    };
    let sync = evaluate_serve_with(&cfg, &opts(false), &perf);
    let spec = evaluate_serve_with(&cfg, &opts(true), &perf);
    assert_eq!(
        fingerprint(&sync.report),
        fingerprint(&spec.report),
        "speculative planning changed eval results"
    );
    assert!(
        spec.serve.spec_plan_hits > 0,
        "quiet rounds must reuse their staged plan (hits {}, misses {})",
        spec.serve.spec_plan_hits,
        spec.serve.spec_plan_misses
    );
    assert!(
        spec.serve.spec_plan_misses > 0,
        "mid-run admissions must force staged-plan repairs (hits {}, misses {})",
        spec.serve.spec_plan_hits,
        spec.serve.spec_plan_misses
    );
    // per-shard counters fold to the report totals
    let hits: u64 = spec.serve.shard_stats.iter().map(|s| s.spec_plan_hits).sum();
    let misses: u64 = spec.serve.shard_stats.iter().map(|s| s.spec_plan_misses).sum();
    assert_eq!(hits, spec.serve.spec_plan_hits);
    assert_eq!(misses, spec.serve.spec_plan_misses);
}

#[test]
fn repeated_async_serves_are_stable_and_leak_free() {
    // AsyncLm joins its completion worker on drop, so back-to-back async
    // serves must neither accumulate state nor wobble: three runs in a row,
    // all byte-identical.
    let cfg = cfg(PolicySpec::Rebase);
    let perf = PerfModel::new(H100_NVL, true, 8);
    let opts = ServeOptions {
        concurrency: 8,
        shards: 2,
        capacity_tokens: DEFAULT_KV_CAPACITY * 2,
        ..Default::default()
    }
    .async_decoded(true);
    let first = fingerprint(&evaluate_serve_with(&cfg, &opts, &perf).report);
    for run in 1..3 {
        let again = fingerprint(&evaluate_serve_with(&cfg, &opts, &perf).report);
        assert_eq!(first, again, "async serve run {run} diverged from run 0");
    }
}

/// Sorted schedule-invariant identities of a serve's controller decisions.
fn decision_identities(
    serve: &ets::coordinator::ServeReport,
) -> Vec<(u64, u8, u64, usize, usize, usize)> {
    let mut ids: Vec<_> = serve.budget_decisions.iter().map(|d| d.identity()).collect();
    ids.sort_unstable();
    ids
}

/// Per-shard reclaimed/granted block counters must reconcile with the
/// decision log grouped by shard, and fold to the report totals.
fn reconcile_budget(serve: &ets::coordinator::ServeReport) {
    let mut reclaimed = vec![0u64; serve.shards];
    let mut granted = vec![0u64; serve.shards];
    let mut moves = 0u64;
    for d in &serve.budget_decisions {
        if d.width_to != d.width_from {
            moves += 1;
        }
        if d.width_to < d.width_from {
            reclaimed[d.shard] += d.blocks as u64;
        } else {
            granted[d.shard] += d.blocks as u64;
        }
    }
    for st in &serve.shard_stats {
        assert_eq!(
            st.reclaimed_kv_blocks, reclaimed[st.shard],
            "shard {} reclaimed blocks do not reconcile with its decisions",
            st.shard
        );
        assert_eq!(
            st.granted_kv_blocks, granted[st.shard],
            "shard {} granted blocks do not reconcile with its decisions",
            st.shard
        );
    }
    assert_eq!(serve.reclaimed_kv_blocks, reclaimed.iter().sum::<u64>());
    assert_eq!(serve.granted_kv_blocks, granted.iter().sum::<u64>());
    assert_eq!(
        serve.width_shrinks + serve.width_grants,
        moves,
        "every applied decision must be counted exactly once"
    );
}

#[test]
fn adaptive_budget_matrix_is_deterministic_across_shards_and_modes() {
    // Adaptive mode is its own serving mode (the controller changes *what*
    // is searched), so its cells are compared among themselves: shards ∈
    // {1, 2, 4} × pipeline × prefix-share × async-decode must fold to
    // byte-identical per-problem results AND a byte-identical controller
    // decision log (scores are pure functions of committed per-session
    // telemetry at fixed step indices — placement can only move the
    // `shard` field, which the identity excludes).
    let cfg = cfg(PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 });
    let run = |shards: usize, pipeline: bool, share: bool, async_decode: bool| {
        let opts = ServeOptions {
            concurrency: 8,
            capacity_tokens: DEFAULT_KV_CAPACITY * shards,
            shards,
            pipeline,
            prefix_share: share,
            ..Default::default()
        }
        .async_decoded(async_decode)
        .adaptive_budgeted(true);
        let perf = PerfModel::new(H100_NVL, true, 8);
        evaluate_serve_with(&cfg, &opts, &perf)
    };
    let base = run(1, false, false, false);
    let base_fp = fingerprint(&base.report);
    let base_ids = decision_identities(&base.serve);
    assert!(base.serve.adaptive_budget);
    assert!(
        base.serve.width_shrinks + base.serve.width_grants >= 1,
        "the synthetic mixed-difficulty set must trigger at least one \
         reallocation (decisions: {:?})",
        base.serve.budget_decisions
    );
    reconcile_budget(&base.serve);
    for shards in [1usize, 2, 4] {
        for (pipeline, share, async_decode) in [
            (false, false, false),
            (true, false, false),
            (false, true, false),
            (false, false, true),
            (true, true, true),
        ] {
            let r = run(shards, pipeline, share, async_decode);
            assert_eq!(
                base_fp,
                fingerprint(&r.report),
                "adaptive shards={shards} pipeline={pipeline} share={share} \
                 async={async_decode} changed results"
            );
            assert_eq!(
                base_ids,
                decision_identities(&r.serve),
                "adaptive shards={shards} pipeline={pipeline} share={share} \
                 async={async_decode} changed the decision log"
            );
            reconcile_budget(&r.serve);
        }
    }
    // off-mode is bit-for-bit the pre-controller serve: no decisions, no
    // reallocation telemetry, no calibration folded into admission
    let perf = PerfModel::new(H100_NVL, true, 8);
    let off = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(8), &perf);
    assert!(!off.serve.adaptive_budget);
    assert!(off.serve.budget_decisions.is_empty());
    assert_eq!(off.serve.width_shrinks + off.serve.width_grants, 0);
    assert_eq!(off.serve.reclaimed_kv_blocks + off.serve.granted_kv_blocks, 0);
}

#[test]
fn adaptive_budget_is_capacity_invariant_and_reallocates_under_pressure() {
    // The controller reads only committed telemetry, so a hard KV budget
    // tight enough to gate admission and preempt sessions must leave both
    // the per-problem results and the decision log byte-identical to the
    // ample adaptive run — pressure may reorder *scheduling*, never
    // *decisions*.
    let cfg = cfg(PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 });
    let perf = PerfModel::new(H100_NVL, true, 8);
    let ample = evaluate_serve_with(
        &cfg,
        &ServeOptions::with_concurrency(8).adaptive_budgeted(true),
        &perf,
    );
    let base_fp = fingerprint(&ample.report);
    let base_ids = decision_identities(&ample.serve);
    let solo_peak = ample
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    let tight_tokens = 2 * solo_peak + 4096;
    assert!(
        ample.serve.peak_resident_kv_tokens > tight_tokens,
        "precondition: ample adaptive peak {} must oversubscribe the tight \
         budget {}",
        ample.serve.peak_resident_kv_tokens,
        tight_tokens
    );
    let capped = evaluate_serve_with(
        &cfg,
        &ServeOptions {
            concurrency: 8,
            capacity_tokens: tight_tokens,
            block_size: 16,
            ..Default::default()
        }
        .adaptive_budgeted(true),
        &perf,
    );
    assert_eq!(
        base_fp,
        fingerprint(&capped.report),
        "a tight capacity changed adaptive search results"
    );
    assert_eq!(
        base_ids,
        decision_identities(&capped.serve),
        "a tight capacity changed the adaptive decision log"
    );
    assert!(
        capped.serve.kv_pressure_events() > 0,
        "tight adaptive budget produced no pressure events"
    );
    assert!(capped.serve.peak_used_blocks <= capped.serve.total_blocks);
    reconcile_budget(&capped.serve);
    // sharded tight cells: the migration-matrix budget shape — identical
    // results and decisions again, with per-shard reconciliation
    let global_budget = 4 * (solo_peak + 4096);
    for shards in [2usize, 4] {
        let opts = ServeOptions {
            concurrency: 8,
            capacity_tokens: global_budget,
            block_size: 16,
            shards,
            ..Default::default()
        }
        .adaptive_budgeted(true);
        let r = evaluate_serve_with(&cfg, &opts, &perf);
        assert_eq!(
            base_fp,
            fingerprint(&r.report),
            "tight adaptive shards={shards} changed results"
        );
        assert_eq!(
            base_ids,
            decision_identities(&r.serve),
            "tight adaptive shards={shards} changed the decision log"
        );
        assert!(r.serve.peak_used_blocks <= r.serve.total_blocks);
        reconcile_budget(&r.serve);
    }
}

#[test]
fn trace_is_read_only() {
    // The observability plane must be provably invisible: tracing and the
    // latency histograms on or off may not move a single result byte OR a
    // single controller decision. Adaptive mode is on so the decision log
    // exists as a second identity surface beyond the per-problem results.
    let cfg = cfg(PolicySpec::Ets { lambda_b: 1.5, lambda_d: 1.0 });
    let perf = PerfModel::new(H100_NVL, true, 8);
    let run = |trace: bool, hists: bool| {
        let opts = ServeOptions {
            concurrency: 8,
            capacity_tokens: DEFAULT_KV_CAPACITY * 2,
            shards: 2,
            ..Default::default()
        }
        .adaptive_budgeted(true)
        .traced(trace)
        .latency_histograms(hists);
        evaluate_serve_with(&cfg, &opts, &perf)
    };
    let bare = run(false, false);
    let plain = run(false, true);
    let traced = run(true, true);
    let base_fp = fingerprint(&bare.report);
    let base_ids = decision_identities(&bare.serve);
    for (name, r) in [("histograms", &plain), ("tracing + histograms", &traced)] {
        assert_eq!(base_fp, fingerprint(&r.report), "{name} changed search results");
        assert_eq!(
            base_ids,
            decision_identities(&r.serve),
            "{name} changed the controller decision log"
        );
    }
    // the switches actually switch
    assert!(bare.serve.trace.is_none() && plain.serve.trace.is_none());
    assert!(bare.serve.latency.completion.is_empty());
    assert_eq!(plain.serve.latency.completion.count(), cfg.n_problems as u64);
    assert_eq!(plain.serve.latency.ttft.count(), cfg.n_problems as u64);
    assert_eq!(plain.serve.latency.tpot.count(), cfg.n_problems as u64);
    // modeled-time request latencies are schedule facts, not wall noise:
    // recording them twice yields the same histograms bit for bit
    assert_eq!(plain.serve.latency, run(false, true).serve.latency);
    assert_eq!(traced.serve.latency, plain.serve.latency);
    let trace = traced.serve.trace.as_ref().expect("traced run carries a trace");
    assert_eq!(trace.dropped, 0, "default ring capacity must not drop events");
    assert_eq!(trace.count("admitted"), cfg.n_problems as u64);
    assert_eq!(trace.count("finished"), cfg.n_problems as u64);
    assert!(!trace.modeled.is_empty(), "modeled track must carry the sessions");
    // and the run's whole event stream reconciles against the ledgers
    let audit = ets::obs::audit::reconcile(&traced.serve).expect("traced");
    assert!(audit.ok(), "trace/ledger audit failed:\n{}", audit.render());
}

#[test]
fn modeled_trace_track_is_byte_identical_across_scheduling_modes() {
    // The identity-bearing half of the trace: the modeled session track is
    // a pure fold of committed outcomes through the perf model, so shards ∈
    // {1, 4} × pipeline × async-decode must serialize it byte-identically —
    // while the executed track legitimately differs (it describes the
    // schedule). This is the trace-level restatement of the repo's
    // determinism contract: scheduling changes when/where/cost, never what.
    let cfg = cfg(PolicySpec::Rebase);
    let perf = PerfModel::new(H100_NVL, true, 8);
    let mut baseline: Option<String> = None;
    for shards in [1usize, 4] {
        for pipeline in [false, true] {
            for async_decode in [false, true] {
                let opts = ServeOptions {
                    concurrency: 8,
                    capacity_tokens: DEFAULT_KV_CAPACITY * shards,
                    shards,
                    pipeline,
                    ..Default::default()
                }
                .async_decoded(async_decode)
                .traced(true);
                let served = evaluate_serve_with(&cfg, &opts, &perf);
                let trace = served.serve.trace.as_ref().expect("traced run");
                assert_eq!(trace.dropped, 0);
                let modeled = trace.modeled_json();
                assert!(modeled.len() > 2, "modeled track must not be empty");
                match &baseline {
                    None => baseline = Some(modeled),
                    Some(b) => assert_eq!(
                        b,
                        &modeled,
                        "shards={shards} pipeline={pipeline} async={async_decode} \
                         changed the modeled trace track"
                    ),
                }
                // the full Chrome document parses and labels every track
                let doc = trace.chrome_json(served.serve.shards).to_string_compact();
                let parsed =
                    ets::util::json::Json::parse(&doc).expect("chrome trace JSON parses");
                let events = parsed
                    .get("traceEvents")
                    .and_then(|e| e.as_arr())
                    .expect("traceEvents array");
                assert!(events.len() >= trace.modeled.len() + trace.exec.len());
            }
        }
    }
}

#[test]
fn trace_audit_reconciles_every_lifecycle_event_under_tight_capacity() {
    // The adversarial audit cell: the proven migration-forcing budget shape
    // with the scheduling-only subsystems stacked on — preemption/resume
    // churn, cross-shard migration, hub imports, cold-tier demotions and
    // restores, speculative planning — must produce an event stream whose
    // per-name counts and token/block sums all reconcile against the
    // aggregate ledgers kept by independent code. (The adaptive width
    // events are audited by `trace_is_read_only` above, whose cells run
    // the controller.)
    let mut cfg = cfg(PolicySpec::Rebase);
    cfg.width = 24;
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 12);
    let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(12), &perf);
    let solo_peak = uncapped
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    let opts = ServeOptions {
        concurrency: 12,
        capacity_tokens: 4 * (solo_peak + 4096),
        block_size: 16,
        shards: 4,
        prefix_share: true,
        ..Default::default()
    }
    .cold_tiered(64 * solo_peak)
    .async_decoded(true)
    .traced(true);
    let capped = evaluate_serve_with(&cfg, &opts, &perf);
    let trace = capped.serve.trace.as_ref().expect("traced run");
    // the cell actually exercised the lifecycle machinery it audits
    assert!(capped.serve.preemptions > 0, "tight budget must preempt");
    assert!(capped.serve.migrations > 0, "tight 4-shard runs must migrate");
    assert!(trace.count("preempted") > 0);
    assert!(trace.count("resumed") > 0);
    assert!(trace.count("migrated") > 0);
    let audit = ets::obs::audit::reconcile(&capped.serve).expect("traced");
    assert_eq!(audit.lines.len(), 15, "every lifecycle ledger gets an audit line");
    assert!(audit.ok(), "trace/ledger audit failed:\n{}", audit.render());
    // the audit is not vacuous: several lines carry non-zero counts
    let nonzero = audit.lines.iter().filter(|l| l.ledger > 0).count();
    assert!(nonzero >= 5, "expected a busy audit, got:\n{}", audit.render());
}

#[test]
fn shard_and_pipeline_matrix_is_invisible_under_pressure_and_tight_shards_migrate() {
    // Fat working sets (width 24) so a per-shard budget sized to one peak
    // working set puts a 3-resident shard under sustained pressure.
    let mut cfg = cfg(PolicySpec::Rebase);
    cfg.width = 24;
    cfg.n_problems = 12;
    let perf = PerfModel::new(H100_NVL, true, 12);
    let uncapped = evaluate_serve_with(&cfg, &ServeOptions::with_concurrency(12), &perf);
    let base = fingerprint(&uncapped.report);
    let solo_peak = uncapped
        .serve
        .outcomes
        .iter()
        .map(|o| o.peak_kv_tokens())
        .max()
        .unwrap() as usize;
    // Global budget = 4 partitions of (one peak working set + slack): at 4
    // shards each shard comfortably fits one resident problem but not its
    // ~3 admitted ones — sustained KvPressure while peers drain and free
    // blocks, which is exactly the cross-shard migration trigger.
    let global_budget = 4 * (solo_peak + 4096);
    for shards in [1usize, 2, 4] {
        for pipeline in [false, true] {
            let opts = ServeOptions {
                concurrency: 12,
                capacity_tokens: global_budget,
                block_size: 16,
                shards,
                pipeline,
                ..Default::default()
            };
            let capped = evaluate_serve_with(&cfg, &opts, &perf);
            assert_eq!(
                base,
                fingerprint(&capped.report),
                "shards={shards} pipeline={pipeline} under a tight budget \
                 changed eval results"
            );
            assert!(
                capped.serve.peak_used_blocks <= capped.serve.total_blocks,
                "hard budget violated at shards={shards}: {} > {}",
                capped.serve.peak_used_blocks,
                capped.serve.total_blocks
            );
            match shards {
                1 => assert_eq!(capped.serve.migrations, 0, "one shard cannot migrate"),
                4 => {
                    assert!(
                        capped.serve.kv_pressure_events() > 0,
                        "a per-shard budget near one working set must pressure \
                         a 3-resident shard"
                    );
                    assert!(
                        capped.serve.migrations > 0,
                        "sustained shard pressure with free peers must migrate \
                         at least one suspended session (pipeline={pipeline})"
                    );
                    assert!(capped.serve.resumes > 0, "migrated sessions must resume");
                    // per-shard ledgers reconcile with the global counter
                    let inbound: u64 =
                        capped.serve.shard_stats.iter().map(|s| s.migrations_in).sum();
                    let outbound: u64 =
                        capped.serve.shard_stats.iter().map(|s| s.migrations_out).sum();
                    assert_eq!(inbound, capped.serve.migrations);
                    assert_eq!(outbound, capped.serve.migrations);
                }
                _ => {}
            }
        }
    }
}
