"""L1 kernel correctness: Pallas (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, matmul, tree_attention
from compile.kernels.ref import (
    decode_attention_ref,
    matmul_ref,
    tree_attention_ref,
)

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")

F32 = np.float32
BF16 = jnp.bfloat16


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(F32)
    return jnp.asarray(x).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == F32 else dict(rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 5),
    h=st.integers(1, 3),
    s=st.integers(1, 24),
    d=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([F32, BF16]),
)
def test_decode_attention_matches_ref(b, h, s, d, seed, dtype):
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, h, d), dtype)
    k = rand(rng, (b, h, s, d), dtype)
    v = rand(rng, (b, h, s, d), dtype)
    length = jnp.asarray(rng.integers(1, s + 1, size=b).astype(np.int32))
    out = decode_attention(q, k, v, length)
    ref = decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(out, dtype=F32), np.asarray(ref, dtype=F32), **tol(dtype)
    )


def test_decode_attention_length_one_uses_single_position():
    # With length=1 the output must equal v[:, :, 0, :] exactly.
    b, h, s, d = 2, 2, 8, 4
    rng = np.random.default_rng(0)
    q = rand(rng, (b, h, d), F32)
    k = rand(rng, (b, h, s, d), F32)
    v = rand(rng, (b, h, s, d), F32)
    length = jnp.asarray(np.ones(b, dtype=np.int32))
    out = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, :, 0, :]), rtol=1e-6)


def test_decode_attention_ignores_padding_garbage():
    # Values beyond `length` must not affect the result.
    b, h, s, d = 1, 1, 10, 8
    rng = np.random.default_rng(1)
    q = rand(rng, (b, h, d), F32)
    k = np.asarray(rand(rng, (b, h, s, d), F32)).copy()
    v = np.asarray(rand(rng, (b, h, s, d), F32)).copy()
    length = jnp.asarray(np.array([4], dtype=np.int32))
    out1 = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), length)
    k[:, :, 4:, :] = 1e4
    v[:, :, 4:, :] = -1e4
    out2 = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), length)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ---------------------------------------------------------------------------
# tree attention
# ---------------------------------------------------------------------------


@given(
    g=st.integers(1, 5),
    h=st.integers(1, 3),
    sp=st.integers(1, 16),
    ss=st.integers(1, 8),
    d=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([F32, BF16]),
)
def test_tree_attention_matches_ref(g, h, sp, ss, d, seed, dtype):
    rng = np.random.default_rng(seed)
    q = rand(rng, (g, h, d), dtype)
    kp = rand(rng, (h, sp, d), dtype)
    vp = rand(rng, (h, sp, d), dtype)
    ks = rand(rng, (g, h, ss, d), dtype)
    vs = rand(rng, (g, h, ss, d), dtype)
    plen = jnp.asarray(rng.integers(1, sp + 1, size=1).astype(np.int32))
    slen = jnp.asarray(rng.integers(1, ss + 1, size=g).astype(np.int32))
    out = tree_attention(q, kp, vp, ks, vs, plen, slen)
    ref = tree_attention_ref(q, kp, vp, ks, vs, plen, slen)
    np.testing.assert_allclose(
        np.asarray(out, dtype=F32), np.asarray(ref, dtype=F32), **tol(dtype)
    )


def test_tree_attention_equals_flat_attention():
    # Concatenating prefix+suffix into one flat KV must give the same result
    # as the two-segment tree kernel (the online-softmax combine is exact).
    g, h, sp, ss, d = 3, 2, 8, 4, 8
    rng = np.random.default_rng(2)
    q = rand(rng, (g, h, d), F32)
    kp = rand(rng, (h, sp, d), F32)
    vp = rand(rng, (h, sp, d), F32)
    ks = rand(rng, (g, h, ss, d), F32)
    vs = rand(rng, (g, h, ss, d), F32)
    plen = jnp.asarray(np.array([sp], dtype=np.int32))
    slen = jnp.asarray(np.full(g, ss, dtype=np.int32))
    out = tree_attention(q, kp, vp, ks, vs, plen, slen)
    # flat equivalent via decode_attention per branch
    k_flat = jnp.concatenate(
        [jnp.broadcast_to(kp[None], (g, h, sp, d)), ks], axis=2
    )
    v_flat = jnp.concatenate(
        [jnp.broadcast_to(vp[None], (g, h, sp, d)), vs], axis=2
    )
    length = jnp.asarray(np.full(g, sp + ss, dtype=np.int32))
    ref = decode_attention_ref(q, k_flat, v_flat, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_tree_attention_suffix_masking():
    # Garbage in masked suffix positions must not leak.
    g, h, sp, ss, d = 2, 1, 4, 6, 4
    rng = np.random.default_rng(3)
    q = rand(rng, (g, h, d), F32)
    kp = rand(rng, (h, sp, d), F32)
    vp = rand(rng, (h, sp, d), F32)
    ks = np.asarray(rand(rng, (g, h, ss, d), F32)).copy()
    vs = np.asarray(rand(rng, (g, h, ss, d), F32)).copy()
    plen = jnp.asarray(np.array([4], dtype=np.int32))
    slen = jnp.asarray(np.array([2, 3], dtype=np.int32))
    out1 = tree_attention(q, kp, vp, jnp.asarray(ks), jnp.asarray(vs), plen, slen)
    ks[0, :, 2:, :] = 77.0
    vs[1, :, 3:, :] = -55.0
    out2 = tree_attention(q, kp, vp, jnp.asarray(ks), jnp.asarray(vs), plen, slen)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 3, 8, 64]),
    k=st.sampled_from([4, 32, 128]),
    n=st.sampled_from([5, 16, 128, 256]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([F32, BF16]),
)
def test_matmul_matches_ref(m, k, n, seed, dtype):
    rng = np.random.default_rng(seed)
    a = rand(rng, (m, k), dtype)
    b = rand(rng, (k, n), dtype)
    out = matmul(a, b)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, dtype=F32),
        np.asarray(ref, dtype=F32),
        rtol=1e-4 if dtype == F32 else 5e-2,
        atol=1e-4 if dtype == F32 else 5e-2,
    )


def test_matmul_identity():
    a = jnp.eye(16, dtype=F32)
    b = jnp.asarray(np.random.default_rng(4).standard_normal((16, 8)).astype(F32))
    np.testing.assert_allclose(np.asarray(matmul(a, b)), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (7, 13, 11), (64, 128, 256)])
def test_matmul_odd_shapes(m, k, n):
    rng = np.random.default_rng(5)
    a = rand(rng, (m, k), F32)
    b = rand(rng, (k, n), F32)
    np.testing.assert_allclose(
        np.asarray(matmul(a, b)), np.asarray(matmul_ref(a, b)), rtol=1e-4, atol=1e-4
    )
