"""AOT pipeline invariants: HLO text must be loadable interchange —
full constants (no elision), parseable header, correct entry shapes."""

import jax
import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_has_no_elided_constants():
    # A function closing over a big constant must dump it fully: the rust
    # loader would otherwise silently zero the weights.
    big = jnp.arange(4096.0)
    text = aot.lower_entry(lambda x: (x * big,), (jax.ShapeDtypeStruct((4096,), jnp.float32),))
    assert "{...}" not in text
    assert "f32[4096]" in text


def test_hlo_text_is_module_with_tuple_root():
    text = aot.lower_entry(
        lambda x: (x + 1.0,), (jax.ShapeDtypeStruct((2, 2), jnp.float32),)
    )
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # return_tuple=True → root is a tuple
    assert "(f32[2,2]" in text


def test_build_artifacts_covers_all_declared_entries():
    names = []
    gen = aot.build_artifacts()
    # don't lower everything (slow) — just verify the generator yields the
    # first artifact with consistent io spec
    name, hlo, io = next(gen)
    names.append(name)
    assert name == f"lm_prefill_b{aot.LM_BATCHES[0]}"
    assert "{...}" not in hlo
    b = aot.LM_BATCHES[0]
    assert io["inputs"][0]["shape"] == [b, aot.LM_CFG.max_seq]
    assert io["outputs"][0]["shape"] == [b, aot.LM_CFG.vocab]


def test_golden_vectors_are_stable():
    g1 = aot.build_golden()
    g2 = aot.build_golden()
    assert g1 == g2
    assert len(g1["prefill_logits_head"]) == 8
    assert all(isinstance(x, float) for x in g1["decode_logits_head"])
    assert 0.0 < min(g1["prm_scores"]) and max(g1["prm_scores"]) < 1.0
    assert abs(g1["embed_norm_row1"] - 1.0) < 1e-3


def test_lm_config_matches_compiled_meta_assumptions():
    cfg = aot.LM_CFG
    # decode KV shape must match what rust reconstructs from meta.json
    params = model.init_lm_params(cfg)
    tok = jnp.zeros((1,), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    kv = jnp.zeros(
        (1, cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32
    )
    logits, k, v = model.lm_decode(params, cfg, tok, pos, kv, kv)
    assert logits.shape == (1, cfg.vocab)
    assert k.shape == kv.shape and v.shape == kv.shape
