"""L2 model checks: shapes, masking semantics, prefill/decode consistency."""

import numpy as np
import jax.numpy as jnp

from compile import model

CFG = model.LmConfig(max_seq=24)  # small seq for fast tests
ECFG = model.EmbedConfig()
PARAMS = model.init_lm_params(CFG)
EPARAMS = model.init_embed_params(ECFG)


def _prompt(b, lens, vocab=CFG.vocab, seed=0):
    rng = np.random.default_rng(seed)
    toks = np.zeros((b, CFG.max_seq), dtype=np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(1, vocab, size=l)
    return jnp.asarray(toks), jnp.asarray(np.array(lens, dtype=np.int32))


def test_prefill_shapes():
    toks, lens = _prompt(2, [5, 9])
    logits, k, v = model.lm_prefill(PARAMS, CFG, toks, lens)
    assert logits.shape == (2, CFG.vocab)
    assert k.shape == (2, CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert v.shape == k.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_prefill_padding_invariance():
    # Tokens beyond `length` must not change the logits.
    toks, lens = _prompt(1, [6])
    l1, _, _ = model.lm_prefill(PARAMS, CFG, toks, lens)
    toks2 = np.asarray(toks).copy()
    toks2[0, 6:] = 99 % CFG.vocab
    l2, _, _ = model.lm_prefill(PARAMS, CFG, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


def test_decode_matches_prefill_extension():
    # prefill(tokens[:n]) + decode(tokens[n]) must equal prefill(tokens[:n+1]).
    n = 7
    toks, lens = _prompt(1, [n + 1], seed=3)
    toks_n = np.asarray(toks).copy()
    toks_n[0, n:] = 0
    _, k, v = model.lm_prefill(
        PARAMS, CFG, jnp.asarray(toks_n), jnp.asarray(np.array([n], np.int32))
    )
    tok_next = jnp.asarray(np.asarray(toks)[0, n : n + 1].astype(np.int32))
    pos = jnp.asarray(np.array([n], dtype=np.int32))
    logits_d, _, _ = model.lm_decode(PARAMS, CFG, tok_next, pos, k, v)
    logits_p, _, _ = model.lm_prefill(PARAMS, CFG, toks, lens)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_p), rtol=5e-4, atol=5e-4
    )


def test_decode_updates_cache_at_pos():
    toks, lens = _prompt(1, [4], seed=5)
    _, k, v = model.lm_prefill(PARAMS, CFG, toks, lens)
    tok = jnp.asarray(np.array([7], np.int32))
    pos = jnp.asarray(np.array([4], np.int32))
    _, k2, v2 = model.lm_decode(PARAMS, CFG, tok, pos, k, v)
    k_np, k2_np = np.asarray(k), np.asarray(k2)
    # position 4 changed, positions 0..3 unchanged
    assert not np.allclose(k_np[:, :, :, 4], k2_np[:, :, :, 4])
    np.testing.assert_allclose(k_np[:, :, :, :4], k2_np[:, :, :, :4])
    v_np, v2_np = np.asarray(v), np.asarray(v2)
    np.testing.assert_allclose(v_np[:, :, :, :4], v2_np[:, :, :, :4])


def test_prm_score_in_unit_interval_and_length_sensitive():
    toks, lens = _prompt(2, [4, 12], seed=7)
    s = np.asarray(model.prm_score(PARAMS, CFG, toks, lens))
    assert s.shape == (2,)
    assert np.all((s > 0) & (s < 1))
    # different prompts give different scores (no degenerate constant head)
    toks2, _ = _prompt(2, [4, 12], seed=8)
    s2 = np.asarray(model.prm_score(PARAMS, CFG, toks2, lens))
    assert not np.allclose(s, s2)


def test_embedder_unit_norm_and_discrimination():
    rng = np.random.default_rng(11)
    toks = np.zeros((3, ECFG.max_seq), dtype=np.int32)
    toks[0, :6] = rng.integers(1, ECFG.vocab, 6)
    toks[1, :6] = toks[0, :6]  # identical sentence
    toks[2, :6] = rng.integers(1, ECFG.vocab, 6)  # different sentence
    lens = jnp.asarray(np.array([6, 6, 6], np.int32))
    e = np.asarray(model.embed_sentence(EPARAMS, ECFG, jnp.asarray(toks), lens))
    norms = np.linalg.norm(e, axis=1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)
    sim_same = float(e[0] @ e[1])
    sim_diff = float(e[0] @ e[2])
    assert sim_same > 0.999
    assert sim_diff < sim_same


def test_weights_are_deterministic_across_processes():
    # init twice -> identical (seeded); different seed -> different
    p1 = model.init_lm_params(CFG)
    p2 = model.init_lm_params(CFG)
    np.testing.assert_array_equal(np.asarray(p1["tok_emb"]), np.asarray(p2["tok_emb"]))
    p3 = model.init_lm_params(model.LmConfig(max_seq=24, seed=1))
    assert not np.allclose(np.asarray(p1["tok_emb"]), np.asarray(p3["tok_emb"]))
