"""Build-time-only package: JAX model (L2) + Pallas kernels (L1) + AOT
lowering. Never imported at serving time — rust loads the HLO artifacts."""
