"""L1/L2 performance analysis (build-time; DESIGN.md §8, EXPERIMENTS.md §Perf).

Pallas interpret mode gives no TPU wallclock, so L1 is assessed structurally:
VMEM working set per grid step from the BlockSpecs (target: fits the ~16 MiB
VMEM with double-buffering headroom) and the MXU utilization character of
each inner op. L2 is assessed from the lowered HLO: op mix, fusion count,
and the absence of recomputation (dynamic-update-slice in-place KV writes).

Run:  cd python && python -m compile.perf_report
"""

import collections
import os
import re

from . import aot

F32 = 4


def mib(nbytes):
    return nbytes / (1 << 20)


def l1_report():
    cfg = aot.LM_CFG
    H, D, S = cfg.n_heads, cfg.head_dim, cfg.max_seq
    G, SP, SS = aot.TREE_G, aot.TREE_SP, aot.TREE_SS
    print("== L1 Pallas kernels: VMEM working set per grid step ==")
    # decode attention: per (b, h) program: q[D] + k[S,D] + v[S,D] + out[D]
    dec = (D + 2 * S * D + D) * F32
    print(f"decode_attention  grid=(B,H)    {mib(dec):8.4f} MiB  "
          f"(q[{D}] + k/v[{S},{D}] + o[{D}])")
    # tree attention: q[D] + kp[SP,D] + vp[SP,D] + ks[SS,D] + vs[SS,D] + o[D]
    tre = (D + 2 * SP * D + 2 * SS * D + D) * F32
    print(f"tree_attention    grid=(G,H)    {mib(tre):8.4f} MiB  "
          f"(prefix[{SP},{D}] shared across {G} branches; suffix[{SS},{D}])")
    hbm_saved = (G - 1) * 2 * SP * D * F32
    print(f"  prefix reuse: index_map ignores branch axis -> "
          f"{mib(hbm_saved):.4f} MiB HBM traffic avoided per head vs per-branch fetch")
    # matmul: tiles bm x bk + bk x bn + acc bm x bn
    bm, bn, bk = 64, 128, 128
    mm = (bm * bk + bk * bn + bm * bn) * F32
    print(f"matmul            grid=(M/{bm},N/{bn},K/{bk}) {mib(mm):8.4f} MiB  "
          f"(a-tile + b-tile + f32 acc)")
    print(f"  all well under 16 MiB VMEM -> double-buffering headroom ~{16/mib(mm):.0f}x")
    # MXU character
    print("MXU: q.k^T / p.v are matvecs per program (VPU-bound at D=32 tiles);")
    print("     matmul inner op is a 64x128x128 f32-accumulate dot -> MXU-shaped.")
    print("     At the paper's scale (D=128 heads, S in the thousands) the same")
    print("     BlockSpecs tile to 128-lane MXU operands; roofline is then the")
    print("     HBM stream of the unique (radix-shared) KV - the quantity ETS minimizes.")


def l2_report():
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    print("\n== L2 lowered HLO op mix (per artifact) ==")
    for name in sorted(os.listdir(art_dir)):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(art_dir, name)).read()
        ops = collections.Counter(
            m.group(1)
            for m in re.finditer(r"=\s+\S+\s+([a-z-]+)\(", text)
        )
        fused = ops.get("fusion", 0)
        dus = ops.get("dynamic-update-slice", 0)
        dots = ops.get("dot", 0)
        whiles = ops.get("while", 0)
        total = sum(ops.values())
        print(f"{name:<26} ops={total:<5} dot={dots:<3} fusion={fused:<3} "
              f"dynamic-update-slice={dus:<2} while={whiles}")
    print("notes: decode KV update lowers to dynamic-update-slice (in-place,")
    print("no recompute); interpret-mode pallas grids lower to while loops;")
    print("XLA fuses elementwise/LN chains around the dots at compile time.")


if __name__ == "__main__":
    l1_report()
    l2_report()
