"""Layer-2: the JAX transformer lowered to the AOT artifacts.

A small GPT-style decoder (the "LM"), a PRM scorer head over the same
encoder trunk, and a separate sentence embedder — the three networks the
paper's serving stack needs (generator, process reward model, math-sentence
embedder). All weights are deterministic functions of a seed and are baked
into the HLO as constants, so the rust runtime only ever feeds tokens /
positions / KV caches.

The decode step's attention runs through the Layer-1 Pallas kernel
(`kernels.decode_attention`), and all FFN matmuls run through the Pallas
tiled matmul, so the L1 schedule is on the decode hot path of the lowered
module. Prefill uses plain jnp causal attention (one-shot, not the hot loop).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import decode_attention, matmul


@dataclasses.dataclass(frozen=True)
class LmConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    max_seq: int = 96
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class EmbedConfig:
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 2
    head_dim: int = 32
    d_ff: int = 128
    max_seq: int = 16
    out_dim: int = 64
    seed: int = 7


def _init(key, shape, scale=0.02):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_lm_params(cfg: LmConfig):
    """Deterministic LM weights: embedding, per-layer attention+FFN, head."""
    key = jax.random.PRNGKey(cfg.seed)
    ks = jax.random.split(key, 4 + 8 * cfg.n_layers)
    p = {
        "tok_emb": _init(ks[0], (cfg.vocab, cfg.d_model)),
        "pos_emb": _init(ks[1], (cfg.max_seq, cfg.d_model)),
        "w_out": _init(ks[2], (cfg.d_model, cfg.vocab)),
        "prm_head": _init(ks[3], (cfg.d_model, 1)),
        "layers": [],
    }
    dm, dh = cfg.d_model, cfg.n_heads * cfg.head_dim
    for layer in range(cfg.n_layers):
        base = 4 + 8 * layer
        p["layers"].append(
            {
                "wq": _init(ks[base + 0], (dm, dh)),
                "wk": _init(ks[base + 1], (dm, dh)),
                "wv": _init(ks[base + 2], (dm, dh)),
                "wo": _init(ks[base + 3], (dh, dm)),
                "w1": _init(ks[base + 4], (dm, cfg.d_ff)),
                "b1": jnp.zeros((cfg.d_ff,), jnp.float32),
                "w2": _init(ks[base + 5], (cfg.d_ff, dm)),
                "b2": jnp.zeros((dm,), jnp.float32),
                "ln1": jnp.ones((dm,), jnp.float32),
                "ln2": jnp.ones((dm,), jnp.float32),
            }
        )
    return p


def _layernorm(x, g):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5)


def _ffn(x2d, layer):
    """Position-wise FFN through the Pallas tiled matmul (L1)."""
    h = matmul(x2d, layer["w1"]) + layer["b1"]
    h = jax.nn.gelu(h)
    return matmul(h, layer["w2"]) + layer["b2"]


def _split_heads(x, n_heads, head_dim):
    # [..., n_heads * head_dim] -> [..., n_heads, head_dim]
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


# ---------------------------------------------------------------------------
# Prefill: full causal attention over a padded prompt; emits the KV cache and
# the next-token logits at position length-1.
# ---------------------------------------------------------------------------


def lm_prefill(params, cfg: LmConfig, tokens, length):
    """tokens: [B, S] int32, length: [B] int32 ->
    (logits [B, V], k [B, L, H, S, D], v [B, L, H, S, D])."""
    b, s = tokens.shape
    h, d = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    pos = jnp.arange(s)
    causal = pos[None, :, None] >= pos[None, None, :]  # [1, Sq, Sk]
    valid = pos[None, None, :] < length[:, None, None]  # [B, 1, Sk]
    mask = jnp.logical_and(causal, valid)  # [B, Sq, Sk]
    ks, vs = [], []
    for layer in params["layers"]:
        xa = _layernorm(x, layer["ln1"])
        q = _split_heads(xa @ layer["wq"], h, d)  # [B, S, H, D]
        k = _split_heads(xa @ layer["wk"], h, d)
        v = _split_heads(xa @ layer["wv"], h, d)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        x = x + attn.reshape(b, s, h * d) @ layer["wo"]
        xf = _layernorm(x, layer["ln2"])
        x = x + _ffn(xf.reshape(b * s, -1), layer).reshape(b, s, -1)
        ks.append(k.transpose(0, 2, 1, 3))  # [B, H, S, D]
        vs.append(v.transpose(0, 2, 1, 3))
    k_cache = jnp.stack(ks, axis=1)  # [B, L, H, S, D]
    v_cache = jnp.stack(vs, axis=1)
    last = jnp.clip(length - 1, 0, s - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0, :]
    logits_out = x_last @ params["w_out"]
    return logits_out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Decode: one token per sequence; Pallas decode attention over the padded KV.
# ---------------------------------------------------------------------------


def lm_decode(params, cfg: LmConfig, token, pos, k_cache, v_cache):
    """token, pos: [B] int32; k_cache/v_cache: [B, L, H, S, D] ->
    (logits [B, V], k', v')."""
    b = token.shape[0]
    h, d = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # [B, dm]
    new_ks, new_vs = [], []
    for li, layer in enumerate(params["layers"]):
        xa = _layernorm(x, layer["ln1"])
        q = _split_heads(xa @ layer["wq"], h, d)  # [B, H, D]
        k_new = _split_heads(xa @ layer["wk"], h, d)
        v_new = _split_heads(xa @ layer["wv"], h, d)
        k_l = k_cache[:, li]  # [B, H, S, D]
        v_l = v_cache[:, li]
        upd = jax.vmap(
            lambda cache, nv, p: jax.lax.dynamic_update_slice(
                cache, nv[:, None, :], (0, p, 0)
            )
        )
        k_l = upd(k_l, k_new, pos)
        v_l = upd(v_l, v_new, pos)
        new_ks.append(k_l)
        new_vs.append(v_l)
        attn = decode_attention(q, k_l, v_l, pos + 1)  # L1 Pallas kernel
        x = x + attn.reshape(b, h * d) @ layer["wo"]
        xf = _layernorm(x, layer["ln2"])
        x = x + _ffn(xf, layer)
    logits = x @ params["w_out"]
    k_out = jnp.stack(new_ks, axis=1)
    v_out = jnp.stack(new_vs, axis=1)
    return logits, k_out, v_out


# ---------------------------------------------------------------------------
# PRM scorer: encoder trunk (prefill weights) + sigmoid head on mean-pooled
# hidden state. Returns a process reward in [0, 1] per sequence.
# ---------------------------------------------------------------------------


def prm_score(params, cfg: LmConfig, tokens, length):
    """tokens: [B, S] int32, length: [B] int32 -> score [B] f32."""
    b, s = tokens.shape
    h, d = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    pos = jnp.arange(s)
    valid = pos[None, None, :] < length[:, None, None]
    causal = pos[None, :, None] >= pos[None, None, :]
    mask = jnp.logical_and(causal, valid)
    for layer in params["layers"]:
        xa = _layernorm(x, layer["ln1"])
        q = _split_heads(xa @ layer["wq"], h, d)
        k = _split_heads(xa @ layer["wk"], h, d)
        v = _split_heads(xa @ layer["wv"], h, d)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        x = x + attn.reshape(b, s, h * d) @ layer["wo"]
        xf = _layernorm(x, layer["ln2"])
        x = x + _ffn(xf.reshape(b * s, -1), layer).reshape(b, s, -1)
    pool_mask = (pos[None, :] < length[:, None]).astype(jnp.float32)
    pooled = (x * pool_mask[:, :, None]).sum(axis=1) / jnp.maximum(
        pool_mask.sum(axis=1, keepdims=True), 1.0
    )
    return jax.nn.sigmoid((pooled @ params["prm_head"])[:, 0])


# ---------------------------------------------------------------------------
# Sentence embedder: 1-layer bidirectional encoder, mean-pool, L2-normalize.
# ---------------------------------------------------------------------------


def init_embed_params(cfg: EmbedConfig):
    key = jax.random.PRNGKey(cfg.seed)
    ks = jax.random.split(key, 10)
    dm, dh = cfg.d_model, cfg.n_heads * cfg.head_dim
    return {
        "tok_emb": _init(ks[0], (cfg.vocab, dm)),
        "pos_emb": _init(ks[1], (cfg.max_seq, dm)),
        "wq": _init(ks[2], (dm, dh)),
        "wk": _init(ks[3], (dm, dh)),
        "wv": _init(ks[4], (dm, dh)),
        "wo": _init(ks[5], (dh, dm)),
        "w1": _init(ks[6], (dm, cfg.d_ff)),
        "w2": _init(ks[7], (cfg.d_ff, dm)),
        "w_out": _init(ks[8], (dm, cfg.out_dim)),
        "ln1": jnp.ones((dm,), jnp.float32),
        "ln2": jnp.ones((dm,), jnp.float32),
    }


def embed_sentence(params, cfg: EmbedConfig, tokens, length):
    """tokens: [B, S] int32, length: [B] int32 -> unit embeddings [B, E]."""
    b, s = tokens.shape
    h, d = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    pos = jnp.arange(s)
    valid = (pos[None, :] < length[:, None]).astype(jnp.float32)  # [B, S]
    mask = valid[:, None, :] * valid[:, :, None]  # bidirectional
    xa = _layernorm(x, params["ln1"])
    q = _split_heads(xa @ params["wq"], h, d)
    k = _split_heads(xa @ params["wk"], h, d)
    v = _split_heads(xa @ params["wv"], h, d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d)
    logits = jnp.where(mask[:, None, :, :] > 0, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    attn = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    x = x + attn.reshape(b, s, h * d) @ params["wo"]
    xf = _layernorm(x, params["ln2"])
    x = x + jax.nn.gelu(xf @ params["w1"]) @ params["w2"]
    pooled = (x * valid[:, :, None]).sum(axis=1) / jnp.maximum(
        valid.sum(axis=1, keepdims=True), 1.0
    )
    e = pooled @ params["w_out"]
    return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)
