"""AOT pipeline: lower the L2 JAX functions (with L1 Pallas kernels inside)
to HLO *text* artifacts the rust runtime loads via PJRT.

Interchange is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import tree_attention

LM_CFG = model.LmConfig()
EMBED_CFG = model.EmbedConfig()

# Batch variants compiled for the serving engine. One executable per shape
# (PJRT requires static shapes); the engine picks the best fit and pads.
LM_BATCHES = (1, 4)
PRM_BATCH = 4
EMBED_BATCH = 8
# tree_attn standalone kernel artifact (L1 bench target from rust)
TREE_G, TREE_SP, TREE_SS = 8, 64, 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked model weights MUST round-trip through
    # the text form (the default elides big literals as `constant({...})`,
    # which the rust-side parser would reject or silently zero).
    return comp.as_hlo_text(print_large_constants=True)


def lower_entry(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build_artifacts():
    """Yield (name, hlo_text, io-spec) for every artifact."""
    params = model.init_lm_params(LM_CFG)
    eparams = model.init_embed_params(EMBED_CFG)
    cfg = LM_CFG
    i32 = jnp.int32
    f32 = jnp.float32
    S = cfg.max_seq
    L, H, D, V = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.vocab
    spec = jax.ShapeDtypeStruct

    for b in LM_BATCHES:
        prefill = functools.partial(model.lm_prefill, params, cfg)
        yield (
            f"lm_prefill_b{b}",
            lower_entry(prefill, (spec((b, S), i32), spec((b,), i32))),
            {
                "inputs": [
                    {"name": "tokens", "shape": [b, S], "dtype": "i32"},
                    {"name": "length", "shape": [b], "dtype": "i32"},
                ],
                "outputs": [
                    {"name": "logits", "shape": [b, V], "dtype": "f32"},
                    {"name": "k", "shape": [b, L, H, S, D], "dtype": "f32"},
                    {"name": "v", "shape": [b, L, H, S, D], "dtype": "f32"},
                ],
            },
        )
        decode = functools.partial(model.lm_decode, params, cfg)
        kv = spec((b, L, H, S, D), f32)
        yield (
            f"lm_decode_b{b}",
            lower_entry(decode, (spec((b,), i32), spec((b,), i32), kv, kv)),
            {
                "inputs": [
                    {"name": "token", "shape": [b], "dtype": "i32"},
                    {"name": "pos", "shape": [b], "dtype": "i32"},
                    {"name": "k", "shape": [b, L, H, S, D], "dtype": "f32"},
                    {"name": "v", "shape": [b, L, H, S, D], "dtype": "f32"},
                ],
                "outputs": [
                    {"name": "logits", "shape": [b, V], "dtype": "f32"},
                    {"name": "k", "shape": [b, L, H, S, D], "dtype": "f32"},
                    {"name": "v", "shape": [b, L, H, S, D], "dtype": "f32"},
                ],
            },
        )

    prm = functools.partial(model.prm_score, params, cfg)
    yield (
        f"prm_score_b{PRM_BATCH}",
        lower_entry(prm, (spec((PRM_BATCH, S), i32), spec((PRM_BATCH,), i32))),
        {
            "inputs": [
                {"name": "tokens", "shape": [PRM_BATCH, S], "dtype": "i32"},
                {"name": "length", "shape": [PRM_BATCH], "dtype": "i32"},
            ],
            "outputs": [{"name": "score", "shape": [PRM_BATCH], "dtype": "f32"}],
        },
    )

    emb = functools.partial(model.embed_sentence, eparams, EMBED_CFG)
    SE, DE = EMBED_CFG.max_seq, EMBED_CFG.out_dim
    yield (
        f"embed_b{EMBED_BATCH}",
        lower_entry(emb, (spec((EMBED_BATCH, SE), i32), spec((EMBED_BATCH,), i32))),
        {
            "inputs": [
                {"name": "tokens", "shape": [EMBED_BATCH, SE], "dtype": "i32"},
                {"name": "length", "shape": [EMBED_BATCH], "dtype": "i32"},
            ],
            "outputs": [{"name": "emb", "shape": [EMBED_BATCH, DE], "dtype": "f32"}],
        },
    )

    g, sp, ss = TREE_G, TREE_SP, TREE_SS
    tree_fn = lambda q, kp, vp, ks, vs, pl_, sl: tree_attention(
        q, kp, vp, ks, vs, pl_, sl
    )
    yield (
        "tree_attn",
        lower_entry(
            tree_fn,
            (
                spec((g, H, D), f32),
                spec((H, sp, D), f32),
                spec((H, sp, D), f32),
                spec((g, H, ss, D), f32),
                spec((g, H, ss, D), f32),
                spec((1,), i32),
                spec((g,), i32),
            ),
        ),
        {
            "inputs": [
                {"name": "q", "shape": [g, H, D], "dtype": "f32"},
                {"name": "k_prefix", "shape": [H, sp, D], "dtype": "f32"},
                {"name": "v_prefix", "shape": [H, sp, D], "dtype": "f32"},
                {"name": "k_suffix", "shape": [g, H, ss, D], "dtype": "f32"},
                {"name": "v_suffix", "shape": [g, H, ss, D], "dtype": "f32"},
                {"name": "prefix_len", "shape": [1], "dtype": "i32"},
                {"name": "suffix_len", "shape": [g], "dtype": "i32"},
            ],
            "outputs": [{"name": "o", "shape": [g, H, D], "dtype": "f32"}],
        },
    )


def build_golden():
    """Deterministic test vectors the rust integration tests replay against
    the compiled artifacts (proving text round-trip preserved the weights)."""
    import numpy as np

    params = model.init_lm_params(LM_CFG)
    eparams = model.init_embed_params(EMBED_CFG)
    cfg = LM_CFG
    S = cfg.max_seq

    # prefill(b=1) on tokens 1..16, then one decode step of token 9 at pos 16
    tokens = np.zeros((1, S), dtype=np.int32)
    tokens[0, :16] = (np.arange(16) % cfg.vocab) + 1
    length = np.array([16], dtype=np.int32)
    logits_p, k, v = model.lm_prefill(params, cfg, jnp.asarray(tokens), jnp.asarray(length))
    tok = np.array([9], dtype=np.int32)
    pos = np.array([16], dtype=np.int32)
    logits_d, _, _ = model.lm_decode(
        params, cfg, jnp.asarray(tok), jnp.asarray(pos), k, v
    )

    # PRM on the same prompt (batch 4: rows 1.. are zero-padded length 1)
    ptoks = np.zeros((PRM_BATCH, S), dtype=np.int32)
    ptoks[0, :16] = tokens[0, :16]
    plens = np.ones((PRM_BATCH,), dtype=np.int32)
    plens[0] = 16
    scores = model.prm_score(params, cfg, jnp.asarray(ptoks), jnp.asarray(plens))

    # embedder on two short "sentences"
    etoks = np.zeros((EMBED_BATCH, EMBED_CFG.max_seq), dtype=np.int32)
    etoks[0, :5] = [3, 1, 4, 1, 5]
    etoks[1, :3] = [2, 7, 1]
    elens = np.ones((EMBED_BATCH,), dtype=np.int32)
    elens[0], elens[1] = 5, 3
    embs = model.embed_sentence(eparams, EMBED_CFG, jnp.asarray(etoks), jnp.asarray(elens))

    def head(x, n=8):
        return [float(f) for f in np.asarray(x).reshape(-1)[:n]]

    return {
        "prefill_tokens16": [int(t) for t in tokens[0, :16]],
        "prefill_logits_head": head(logits_p),
        "decode_token": 9,
        "decode_pos": 16,
        "decode_logits_head": head(logits_d),
        "prm_scores": head(scores, PRM_BATCH),
        "embed_head": head(embs[0], 8),
        "embed_norm_row1": float(np.linalg.norm(np.asarray(embs[1]))),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    meta = {
        "model": {
            "vocab": LM_CFG.vocab,
            "d_model": LM_CFG.d_model,
            "n_layers": LM_CFG.n_layers,
            "n_heads": LM_CFG.n_heads,
            "head_dim": LM_CFG.head_dim,
            "d_ff": LM_CFG.d_ff,
            "max_seq": LM_CFG.max_seq,
        },
        "embed": {"max_seq": EMBED_CFG.max_seq, "out_dim": EMBED_CFG.out_dim},
        "lm_batches": list(LM_BATCHES),
        "prm_batch": PRM_BATCH,
        "embed_batch": EMBED_BATCH,
        "tree_attn": {"g": TREE_G, "sp": TREE_SP, "ss": TREE_SS},
        "artifacts": {},
    }
    for name, hlo, io in build_artifacts():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        meta["artifacts"][name] = io
        print(f"wrote {path} ({len(hlo) / 1e6:.2f} MB)")
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {args.out_dir}/meta.json")
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(build_golden(), f, indent=1)
    print(f"wrote {args.out_dir}/golden.json")


if __name__ == "__main__":
    main()
