"""Tiled Pallas matmul used by the transformer FFN / projection layers.

Classic (M, N, K)-tiled schedule: grid = (M/bm, N/bn, K/bk), f32 accumulator
tile resident in VMEM across the K axis (the revisiting dimension), A/B tiles
streamed per grid step. Tile defaults are MXU-shaped (multiples of 128 lanes);
interpret mode lowers the same schedule to plain HLO for the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim, target):
    """Largest divisor of ``dim`` that is <= target (keeps tiles aligned)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def matmul(a, b, *, bm=64, bn=128, bk=128):
    """``a[M, K] @ b[K, N] -> [M, N]`` with f32 accumulation in VMEM scratch.

    Block sizes clamp to divisors of the problem shape so any (M, N, K)
    works; defaults target an MXU-friendly 64x128x128 tiling.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a, b)
