"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written with
plain ``jax.numpy`` ops only. pytest (``python/tests/``) sweeps shapes and
dtypes with hypothesis and asserts the Pallas outputs match these to tight
tolerances.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, length):
    """Single-step decode attention over a padded KV cache.

    q: [B, H, D]        query for the newest token
    k: [B, H, S, D]     padded key cache
    v: [B, H, S, D]     padded value cache
    length: [B] int32   number of valid cache positions per sequence
    returns: [B, H, D]
    """
    b, h, s, d = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    pos = jnp.arange(s)[None, None, :]
    mask = pos < length[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def tree_attention_ref(q, k_prefix, v_prefix, k_suffix, v_suffix, prefix_len, suffix_len):
    """Shared-prefix ("tree") decode attention.

    G branch queries attend over one *shared* prefix KV segment plus their
    own per-branch suffix KV segment — the KV-sharing pattern ETS promotes.

    q:        [G, H, D]
    k_prefix: [H, SP, D]   shared by all branches
    v_prefix: [H, SP, D]
    k_suffix: [G, H, SS, D] per-branch
    v_suffix: [G, H, SS, D]
    prefix_len: scalar int32 (valid prefix positions)
    suffix_len: [G] int32    (valid suffix positions per branch)
    returns:  [G, H, D]
    """
    g, h, d = q.shape
    sp = k_prefix.shape[1]
    ss = k_suffix.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    qf = q.astype(jnp.float32)

    lp = jnp.einsum("ghd,hsd->ghs", qf, k_prefix.astype(jnp.float32)) * scale
    p_mask = jnp.arange(sp)[None, None, :] < prefix_len
    lp = jnp.where(p_mask, lp, NEG_INF)

    ls = jnp.einsum("ghd,ghsd->ghs", qf, k_suffix.astype(jnp.float32)) * scale
    s_mask = jnp.arange(ss)[None, None, :] < suffix_len[:, None, None]
    ls = jnp.where(s_mask, ls, NEG_INF)

    logits = jnp.concatenate([lp, ls], axis=-1)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    vp = jnp.broadcast_to(v_prefix[None].astype(jnp.float32), (g, h, sp, d))
    vall = jnp.concatenate([vp, v_suffix.astype(jnp.float32)], axis=2)
    out = jnp.einsum("ghs,ghsd->ghd", p, vall)
    return out.astype(q.dtype)


def matmul_ref(a, b):
    """a @ b with f32 accumulation. a: [M, K], b: [K, N] -> [M, N]."""
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(a.dtype)
