"""Pallas attention kernels: padded decode attention and shared-prefix
("tree") attention — the compute hot-spot of PRM-guided tree search.

Hardware adaptation (paper -> TPU, see DESIGN.md):

* The GPU serving stacks the paper builds on (SGLang radix attention, DeFT)
  batch *threadblock loads* of the shared prefix KV. On TPU the analogue is
  the BlockSpec HBM->VMEM schedule: the prefix KV block's ``index_map``
  ignores the branch grid axis, so the same VMEM block is reused for every
  branch instead of being re-fetched per trajectory.
* q.k^T / p.v products map onto the MXU; accumulation is f32 regardless of
  input dtype (bf16-ready).
* The two KV segments (shared prefix, per-branch suffix) are fused with an
  online-softmax rescale, flash-attention style, so full logits are never
  materialized in HBM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Padded decode attention: one program per (batch, head).
# ---------------------------------------------------------------------------


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, s, d):
    """Attention of one query vector over one padded KV segment."""
    q = q_ref[0, 0, :].astype(jnp.float32)  # [D]
    k = k_ref[0, 0, :, :].astype(jnp.float32)  # [S, D]
    v = v_ref[0, 0, :, :].astype(jnp.float32)  # [S, D]
    length = len_ref[pl.program_id(0)]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = (k @ q) * scale  # [S]  (MXU matvec)
    mask = jax.lax.broadcasted_iota(jnp.int32, (s,), 0) < length
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits)
    p = jnp.exp(logits - m)
    acc = p @ v  # [D]
    o_ref[0, 0, :] = (acc / jnp.sum(p)).astype(o_ref.dtype)


def decode_attention(q, k, v, length):
    """Single-token decode attention over a padded KV cache.

    q: [B, H, D]; k, v: [B, H, S, D]; length: [B] int32 -> [B, H, D].
    Grid (B, H); each program holds one [S, D] KV tile in VMEM.
    """
    b, h, d = q.shape
    s = k.shape[2]
    kernel = functools.partial(_decode_attn_kernel, s=s, d=d)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((b,), lambda i, j: (0,)),  # lengths: tiny, whole
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(length, q, k, v)


# ---------------------------------------------------------------------------
# Shared-prefix tree attention: grid (G, H); the prefix KV BlockSpec's
# index_map ignores the branch axis -> one VMEM fetch serves all branches.
# ---------------------------------------------------------------------------


def _tree_attn_kernel(
    plen_ref, slen_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref, o_ref, *, sp, ss, d
):
    g = pl.program_id(0)
    q = q_ref[0, 0, :].astype(jnp.float32)  # [D]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    # Segment 1: shared prefix (same VMEM block for every branch g).
    kp = kp_ref[0, :, :].astype(jnp.float32)  # [SP, D]
    vp = vp_ref[0, :, :].astype(jnp.float32)
    lp = (kp @ q) * scale
    pmask = jax.lax.broadcasted_iota(jnp.int32, (sp,), 0) < plen_ref[0]
    lp = jnp.where(pmask, lp, NEG_INF)
    m1 = jnp.max(lp)
    p1 = jnp.exp(lp - m1)
    l1 = jnp.sum(p1)
    acc1 = p1 @ vp  # [D]

    # Segment 2: per-branch suffix.
    ks = ks_ref[0, 0, :, :].astype(jnp.float32)  # [SS, D]
    vs = vs_ref[0, 0, :, :].astype(jnp.float32)
    ls = (ks @ q) * scale
    smask = jax.lax.broadcasted_iota(jnp.int32, (ss,), 0) < slen_ref[g]
    ls = jnp.where(smask, ls, NEG_INF)
    m2 = jnp.max(ls)
    p2 = jnp.exp(ls - m2)
    l2 = jnp.sum(p2)
    acc2 = p2 @ vs

    # Online-softmax combine (flash-style rescale of the two segments).
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    denom = a1 * l1 + a2 * l2
    out = (a1 * acc1 + a2 * acc2) / denom
    o_ref[0, 0, :] = out.astype(o_ref.dtype)


def tree_attention(q, k_prefix, v_prefix, k_suffix, v_suffix, prefix_len, suffix_len):
    """Shared-prefix decode attention for G branches of one search tree.

    q: [G, H, D]; k_prefix/v_prefix: [H, SP, D] (shared);
    k_suffix/v_suffix: [G, H, SS, D]; prefix_len: [1] int32;
    suffix_len: [G] int32 -> [G, H, D].
    """
    g, h, d = q.shape
    sp = k_prefix.shape[1]
    ss = k_suffix.shape[2]
    kernel = functools.partial(_tree_attn_kernel, sp=sp, ss=ss, d=d)
    return pl.pallas_call(
        kernel,
        grid=(g, h),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((g,), lambda i, j: (0,)),
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
            # prefix KV: index_map ignores the branch axis i — the block is
            # fetched once per head and reused across branches (the KV-sharing
            # the paper's cost model maximizes).
            pl.BlockSpec((1, sp, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, sp, d), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, 1, ss, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ss, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((g, h, d), q.dtype),
        interpret=True,
    )(prefix_len, suffix_len, q, k_prefix, v_prefix, k_suffix, v_suffix)
