"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, so interpret mode lowers them to plain HLO while keeping
the BlockSpec structure that defines the TPU HBM->VMEM schedule (see
DESIGN.md section "Hardware adaptation").
"""

from .tree_attention import decode_attention, tree_attention
from .matmul import matmul

__all__ = ["decode_attention", "tree_attention", "matmul"]
